//! Bench: regenerate **Table 1** (op counts per rounding size) and time
//! the preprocessor that produces it.
//!
//! Run: `cargo bench --bench table1_opcounts`
//!
//! Output: the reproduced table (to compare against the paper row-by-row)
//! plus timing of Algorithm 1 over the whole model per rounding size —
//! preprocessing is one-off/offline in the paper, so the requirement is
//! "cheap enough", not "hot-path fast".

use subaccel::accel::{model_op_sweep, model_ops, TABLE1_ROUNDINGS};
use subaccel::data::load_weights;
use subaccel::nn::{lenet5, lenet5_from_params};
use subaccel::util::{bench, bench_header};

fn main() {
    // Trained weights if available (the paper's setting), random otherwise.
    let model = match load_weights("artifacts/weights.bin") {
        Ok(w) => {
            println!("using trained weights from artifacts/weights.bin");
            lenet5_from_params(&w)
        }
        Err(_) => {
            println!("artifacts missing — falling back to seeded random weights");
            lenet5()
        }
    };

    println!("\n# Table 1 (reproduced)");
    println!(
        "{:>9} {:>10} {:>13} {:>16} {:>9}",
        "rounding", "additions", "subtractions", "multiplications", "total"
    );
    let rows = model_op_sweep(&model, &[1, 1, 32, 32], &TABLE1_ROUNDINGS);
    for r in &rows {
        println!(
            "{:>9} {:>10} {:>13} {:>16} {:>9}",
            r.rounding, r.adds, r.subs, r.muls, r.total
        );
    }
    assert_eq!(rows[0].muls, 405_600, "baseline must match the paper exactly");

    println!("\n# preprocessing cost (Algorithm 1 over all conv layers)");
    println!("{}", bench_header());
    for &r in &[0.0f32, 0.05, 0.3] {
        let res = bench(&format!("preprocess rounding={r}"), 3, 20, || {
            model_ops(&model, &[1, 1, 32, 32], r).subs
        });
        println!("{}", res.report());
    }
}
