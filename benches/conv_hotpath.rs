//! Bench: the convolution hot path across every engine in the stack —
//! dense rust conv, paired subtractor unit (serial vs the parallel
//! [`ConvEngine`]), and the two PJRT artifacts (Pallas-kernel and
//! XLA-native). This is the §Perf measurement harness
//! (EXPERIMENTS.md §Perf).
//!
//! Acceptance gate for the engine: on a multi-core host, N threads must
//! be ≥1.5× faster than serial on the large batched geometry, never
//! slower with 1 thread, and outputs must agree within 1e-5 (they are
//! bit-identical by construction).
//!
//! Run: `cargo bench --bench conv_hotpath`

use subaccel::accel::{
    autotune_conv, tile_rows_heuristic, AutotuneBudget, ConvEngine, SubConv2d, TileCache,
};
use subaccel::data::load_weights;
use subaccel::nn::layers::conv2d;
use subaccel::nn::{lenet5, lenet5_from_params, PairedModel};
use subaccel::runtime::{LeNet5Executor, Runtime, Variant};
use subaccel::tensor::Tensor;
use subaccel::util::{baseline_ns, bench, bench_header, bench_smoke, JsonReport, Rng};

fn main() {
    let mut rng = Rng::seed_from_u64(42);
    // machine-readable trajectory (SUBACCEL_BENCH_JSON=BENCH_8.json via
    // scripts/check.sh --smoke); no-op when the env var is unset
    let mut json = JsonReport::from_env();
    println!("{}", bench_header());

    // --- L3 kernels: dense vs paired, LeNet C3 geometry -----------------
    let x = Tensor::new(&[1, 6, 14, 14], rng.vec_range(6 * 14 * 14, -1.0, 1.0));
    let w = Tensor::new(&[16, 6, 5, 5], rng.vec_range(16 * 150, -0.3, 0.3));
    let b = Tensor::new(&[16], rng.vec_range(16, -0.1, 0.1));
    let r = bench("rust dense conv c3 (1 img)", 5, 50, || conv2d(&x, &w, &b, 1, 0).0.len());
    println!("{}", r.report());
    for rounding in [0.05f32, 0.3] {
        let sc = SubConv2d::compile(&w, &b, rounding);
        let label = format!("rust subconv c3 r={rounding} ({} pairs)", sc.total_pairs());
        let r = bench(&label, 5, 50, || sc.forward(&x).0.len());
        println!("{}", r.report());
    }

    // --- serial vs parallel engine (batched) ----------------------------
    // C3 geometry at batch 8, plus a wider layer where sharding pays.
    let n_threads = ConvEngine::host_threads();
    let e1 = ConvEngine::new(1).expect("1-thread engine");
    let en = ConvEngine::new(n_threads).expect("N-thread engine");
    println!("\n# packed engine, serial vs 1 thread vs {n_threads} threads");
    let x8 = Tensor::new(&[8, 6, 14, 14], rng.vec_range(8 * 6 * 14 * 14, -1.0, 1.0));
    let wide_x = Tensor::new(&[8, 16, 28, 28], rng.vec_range(8 * 16 * 28 * 28, -1.0, 1.0));
    let wide_w = Tensor::new(&[48, 16, 3, 3], rng.vec_range(48 * 16 * 9, -0.3, 0.3));
    let wide_b = Tensor::new(&[48], rng.vec_range(48, -0.1, 0.1));
    for (name, xx, ww, bb, iters) in [
        ("c3 b8", &x8, &w, &b, 40),
        ("wide b8", &wide_x, &wide_w, &wide_b, 15),
    ] {
        let sc = SubConv2d::compile(ww, bb, 0.05);
        let serial = bench(&format!("subconv {name} serial"), 3, iters, || sc.forward(xx).0.len());
        println!("{}", serial.report());
        let r1 = bench(&format!("subconv {name} engine t=1"), 3, iters, || {
            sc.forward_with(&e1, xx).unwrap().0.len()
        });
        println!("{}", r1.report());
        let rn = bench(&format!("subconv {name} engine t={n_threads}"), 3, iters, || {
            sc.forward_with(&en, xx).unwrap().0.len()
        });
        println!("{}", rn.report());
        let speedup = serial.mean.as_secs_f64() / rn.mean.as_secs_f64();
        println!("  -> {name}: {n_threads}-thread speedup {speedup:.2}x over serial");
        // correctness gate: all three paths agree within 1e-5
        let want = sc.forward(xx).0;
        for (t, eng) in [(1usize, &e1), (n_threads, &en)] {
            let got = sc.forward_with(eng, xx).unwrap().0;
            let diff = got.max_abs_diff(&want);
            assert!(diff <= 1e-5, "engine t={t} diverged from serial: max |Δ| {diff}");
        }
        let ops = (sc.total_pairs() + sc.total_unpaired()) as f64;
        json.push(&r1, &[("ops_per_row", ops), ("threads", 1.0)]);
        json.push(&rn, &[("ops_per_row", ops), ("threads", n_threads as f64)]);
    }

    // --- tiled microkernel vs untiled reference, AlexNet-class conv ------
    // Acceptance gate (ISSUE 8): the tile-blocked kernel must beat the
    // reference compute_rows by ≥ 1.4× single-threaded on an
    // AlexNet-class layer, and match it bit-for-bit. conv2 geometry:
    // 96→256 channels, 5×5, pad 2 ⇒ k_len 2400, 27×27 = 729 rows —
    // the reference path re-streams ~4.8 MB of tap tables per row.
    let ax = Tensor::new(&[1, 96, 27, 27], rng.vec_range(96 * 27 * 27, -1.0, 1.0));
    let aw = Tensor::new(&[256, 96, 5, 5], rng.vec_range(256 * 96 * 25, -0.3, 0.3));
    let ab = Tensor::new(&[256], rng.vec_range(256, -0.1, 0.1));
    let asc = SubConv2d::compile_geo(&aw, &ab, 0.05, 1, 2);
    let tile = e1.tile_rows().unwrap_or_else(|| {
        tile_rows_heuristic(asc.packed().k_len(), asc.packed().cout(), asc.packed().total_taps())
    });
    println!("\n# tiled microkernel vs reference, alexnet-class conv2 (tile {tile} rows)");
    let rref = bench("alexconv2 reference compute_rows t=1", 1, 5, || {
        ConvEngine::forward_packed_reference(asc.packed(), asc.bias(), asc.geometry(), &ax)
            .unwrap()
            .0
            .len()
    });
    println!("{}", rref.report());
    let rtiled = bench("alexconv2 tiled t=1", 1, 5, || asc.forward_with(&e1, &ax).unwrap().0.len());
    let tiled_speedup = rref.mean.as_secs_f64() / rtiled.mean.as_secs_f64();
    println!("{}  [{tiled_speedup:.2}x vs reference]", rtiled.report());
    // bit-identity gate: tiling must not change a single bit
    let want =
        ConvEngine::forward_packed_reference(asc.packed(), asc.bias(), asc.geometry(), &ax)
            .unwrap()
            .0;
    let got = asc.forward_with(&e1, &ax).unwrap().0;
    assert_eq!(got.data(), want.data(), "tiled kernel diverged from reference");
    let aops = ((asc.total_pairs() + asc.total_unpaired()) * 27 * 27) as f64;
    json.push(&rref, &[("ops", aops), ("threads", 1.0), ("tile_rows", 0.0)]);
    json.push(&rtiled, &[("ops", aops), ("threads", 1.0), ("tile_rows", tile as f64)]);

    // --- plan-warm autotune sweep, same alexnet-class layer --------------
    // Acceptance gate (ISSUE 10): the measured sweep's winning tile must
    // not regress the static-heuristic tile by more than 10% on this
    // layer, and when scripts/check.sh --smoke passes the previous
    // trajectory through SUBACCEL_BENCH_BASELINE, the fresh autotuned
    // number is also gated against the recorded one — but only when both
    // sides are real measurements (smoke numbers prove shape, not speed).
    let budget = AutotuneBudget::measured(if bench_smoke() { 1 } else { 3 });
    let d = autotune_conv(
        &e1,
        asc.packed(),
        asc.bias().data(),
        asc.geometry(),
        &[1, 96, 27, 27],
        "alexconv2",
        &budget,
    );
    println!(
        "\n# plan-warm autotune, alexconv2: tile {} rows ({}, {} candidates swept)",
        d.tile_rows,
        d.source.as_str(),
        d.candidates
    );
    let mut aout = Vec::new();
    let rheur = bench("alexconv2 heuristic tile t=1", 1, 5, || {
        e1.forward_packed_tiled_slice_into(
            asc.packed(),
            asc.bias().data(),
            asc.geometry(),
            ax.data(),
            &[1, 96, 27, 27],
            None,
            &mut aout,
        )
        .unwrap();
        aout.len()
    });
    println!("{}", rheur.report());
    let rtuned = bench("alexconv2 autotuned t=1", 1, 5, || {
        e1.forward_packed_tiled_slice_into(
            asc.packed(),
            asc.bias().data(),
            asc.geometry(),
            ax.data(),
            &[1, 96, 27, 27],
            Some(d.tile_rows),
            &mut aout,
        )
        .unwrap();
        aout.len()
    });
    let tuned_vs_heur = rheur.mean.as_secs_f64() / rtuned.mean.as_secs_f64();
    println!("{}  [{tuned_vs_heur:.2}x vs heuristic tile]", rtuned.report());
    // bit-identity gate: the autotuned tile is just another regrouping
    assert_eq!(aout.as_slice(), want.data(), "autotuned tile diverged from reference");
    if !bench_smoke() {
        assert!(
            rtuned.mean.as_secs_f64() <= rheur.mean.as_secs_f64() * 1.10,
            "autotuned tile {} regressed >10% vs heuristic: {:?} vs {:?}",
            d.tile_rows,
            rtuned.mean,
            rheur.mean
        );
    }
    json.push(&rheur, &[("ops", aops), ("threads", 1.0), ("tile_rows", tile as f64)]);
    json.push(&rtuned, &[("ops", aops), ("threads", 1.0), ("tile_rows", d.tile_rows as f64)]);
    TileCache::record(&mut json, "alexconv2", std::slice::from_ref(&d));
    // cross-run regression gate against the recorded trajectory
    let baseline = std::env::var("SUBACCEL_BENCH_BASELINE")
        .ok()
        .and_then(|p| baseline_ns(&p, "alexconv2 autotuned t=1"));
    match baseline {
        Some((base_ns, false)) if !bench_smoke() => {
            let fresh_ns = rtuned.mean.as_nanos() as f64;
            assert!(
                fresh_ns <= base_ns * 1.10,
                "autotuned alexconv2 regressed >10% vs recorded trajectory: \
                 {fresh_ns:.0}ns vs {base_ns:.0}ns"
            );
            println!("  -> trajectory gate OK: {fresh_ns:.0}ns vs recorded {base_ns:.0}ns");
        }
        Some(_) => println!("SKIP trajectory gate: smoke-mode numbers on one side"),
        None => println!("SKIP trajectory gate: no recorded baseline entry"),
    }

    // --- whole-network plan executor (zero-alloc steady state) ----------
    let m = lenet5();
    let pm = PairedModel::compile(&m, 0.05);
    let plan = pm.compiled().plan(&[8, 1, 32, 32]).expect("plan");
    let mut exe = plan.into_executor();
    // warm + one-shot tile sweep (deterministic cost-model mode); a
    // previous trajectory warm-starts the sweep when scripts/check.sh
    // --smoke passes it back through SUBACCEL_AUTOTUNE_CACHE
    let cache = TileCache::from_env();
    let decisions =
        exe.warm_autotuned(&e1, &AutotuneBudget::default(), cache.as_ref()).to_vec();
    let xb = Tensor::new(&[8, 1, 32, 32], rng.vec_range(8 * 1024, 0.0, 1.0));
    println!("\n# whole-network plan executor, lenet5 b8 (rounding 0.05)");
    for d in &decisions {
        println!("  autotune {}: tile {} rows ({})", d.layer, d.tile_rows, d.source.as_str());
    }
    TileCache::record(&mut json, "lenet5", &decisions);
    let mut out = Vec::new();
    let r = bench("lenet5 plan forward_into b8 t=1", 3, 30, || {
        exe.forward_into(&e1, &xb, &mut out).expect("plan forward");
        out.len()
    });
    println!("{}", r.report());
    let rn = bench(&format!("lenet5 plan forward_into b8 t={n_threads}"), 3, 30, || {
        exe.forward_into(&en, &xb, &mut out).expect("plan forward");
        out.len()
    });
    println!("{}", rn.report());
    // correctness gate: the warm plan path is bit-identical to the
    // PairedModel cache path, on both engines
    for eng in [&e1, &en] {
        let want = pm.infer_with(eng, &xb).expect("paired forward");
        let got = exe.infer(eng, &xb).expect("plan infer");
        assert_eq!(got, want, "plan executor diverged from PairedModel");
    }
    json.push(&r, &[("threads", 1.0)]);
    json.push(&rn, &[("threads", n_threads as f64)]);

    // the CPU-path trajectory is complete here; write it before the
    // artifact-gated sections so CI gets a file even without artifacts
    if let Some(p) = json.finish().expect("write bench json") {
        println!("\nwrote {p}");
    }

    // --- whole-model paths ----------------------------------------------
    let Ok(weights) = load_weights("artifacts/weights.bin") else {
        println!("SKIP model/PJRT benches: run `make artifacts` first");
        return;
    };
    let model = lenet5_from_params(&weights);
    let img = Tensor::new(&[1, 1, 32, 32], rng.vec_range(1024, 0.0, 1.0));
    let r = bench("rust engine lenet5 fwd (1 img)", 3, 30, || model.infer(&img).len());
    println!("{}", r.report());

    let rt = Runtime::cpu().expect("PJRT CPU client");
    for (variant, name) in [(Variant::XlaNative, "xla-native"), (Variant::Pallas, "pallas")] {
        for batch in [1usize, 8] {
            let exe = match LeNet5Executor::load(&rt, "artifacts", variant, batch, &weights) {
                Ok(e) => e,
                Err(e) => {
                    println!("SKIP {name} b{batch}: {e:#}");
                    continue;
                }
            };
            let input = Tensor::new(
                &[batch, 1, 32, 32],
                rng.vec_range(batch * 1024, 0.0, 1.0),
            );
            let iters = if matches!(variant, Variant::Pallas) { 10 } else { 50 };
            let r = bench(&format!("pjrt {name} lenet5 b{batch}"), 2, iters, || {
                exe.execute(&input).expect("execute").len()
            });
            println!("{} [{:.1} img/s]", r.report(), r.throughput(batch));
        }
    }
}
