//! Bench: the convolution hot path across every engine in the stack —
//! dense rust conv, paired subtractor unit (rust), and the two PJRT
//! artifacts (Pallas-kernel and XLA-native). This is the §Perf
//! measurement harness (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench conv_hotpath`

use subaccel::accel::SubConv2d;
use subaccel::data::load_weights;
use subaccel::nn::layers::conv2d;
use subaccel::nn::lenet5_from_params;
use subaccel::runtime::{LeNet5Executor, Runtime, Variant};
use subaccel::tensor::Tensor;
use subaccel::util::{bench, bench_header, Rng};

fn main() {
    let mut rng = Rng::seed_from_u64(42);
    println!("{}", bench_header());

    // --- L3 kernels: dense vs paired, LeNet C3 geometry -----------------
    let x = Tensor::new(&[1, 6, 14, 14], rng.vec_range(6 * 14 * 14, -1.0, 1.0));
    let w = Tensor::new(&[16, 6, 5, 5], rng.vec_range(16 * 150, -0.3, 0.3));
    let b = Tensor::new(&[16], rng.vec_range(16, -0.1, 0.1));
    let r = bench("rust dense conv c3 (1 img)", 5, 50, || conv2d(&x, &w, &b, 1, 0).0.len());
    println!("{}", r.report());
    for rounding in [0.05f32, 0.3] {
        let sc = SubConv2d::compile(&w, &b, rounding);
        let label = format!("rust subconv c3 r={rounding} ({} pairs)", sc.total_pairs());
        let r = bench(&label, 5, 50, || sc.forward(&x).0.len());
        println!("{}", r.report());
    }

    // --- whole-model paths ----------------------------------------------
    let Ok(weights) = load_weights("artifacts/weights.bin") else {
        println!("SKIP model/PJRT benches: run `make artifacts` first");
        return;
    };
    let model = lenet5_from_params(&weights);
    let img = Tensor::new(&[1, 1, 32, 32], rng.vec_range(1024, 0.0, 1.0));
    let r = bench("rust engine lenet5 fwd (1 img)", 3, 30, || model.infer(&img).len());
    println!("{}", r.report());

    let rt = Runtime::cpu().expect("PJRT CPU client");
    for (variant, name) in [(Variant::XlaNative, "xla-native"), (Variant::Pallas, "pallas")] {
        for batch in [1usize, 8] {
            let exe = match LeNet5Executor::load(&rt, "artifacts", variant, batch, &weights) {
                Ok(e) => e,
                Err(e) => {
                    println!("SKIP {name} b{batch}: {e:#}");
                    continue;
                }
            };
            let input = Tensor::new(
                &[batch, 1, 32, 32],
                rng.vec_range(batch * 1024, 0.0, 1.0),
            );
            let iters = if matches!(variant, Variant::Pallas) { 10 } else { 50 };
            let r = bench(&format!("pjrt {name} lenet5 b{batch}"), 2, iters, || {
                exe.execute(&input).expect("execute").len()
            });
            println!("{} [{:.1} img/s]", r.report(), r.throughput(batch));
        }
    }
}
