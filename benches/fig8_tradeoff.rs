//! Bench: regenerate **Fig 8** — accuracy vs power/area savings per
//! rounding size — under both hardware cost models (published-ratio and
//! paper-calibrated), plus the PE-array delay check.
//!
//! Run: `cargo bench --bench fig8_tradeoff`
//!
//! Expected shape (paper): savings grow steeply until rounding ≈ 0.05
//! then flatten; accuracy is flat until ≈ 0.05 then collapses. Headline
//! row (0.05): −32.03 % power, −24.59 % area, −0.1 % accuracy.

use subaccel::accel::{model_op_sweep, LayerPairing, TABLE1_ROUNDINGS};
use subaccel::data::{load_dataset, load_weights};
use subaccel::hw::{savings_report, CostModel, PeArrayConfig, PeArraySim};
use subaccel::nn::lenet5_from_params;
use subaccel::util::bench_smoke;

fn main() {
    let weights = match load_weights("artifacts/weights.bin") {
        Ok(w) => w,
        Err(e) => {
            println!("SKIP: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let ds = load_dataset("artifacts/dataset.bin").expect("dataset.bin");
    let model = lenet5_from_params(&weights);
    let rows = model_op_sweep(&model, &[1, 1, 32, 32], &TABLE1_ROUNDINGS);
    let baseline = &rows[0];
    let n = if bench_smoke() { 20 } else { 500 }.min(ds.n);

    let base_acc = accuracy(&model, &ds, n, 0.0);
    println!("# Fig 8 — accuracy vs savings ({n} images; baseline accuracy {:.2}%)", base_acc * 100.0);

    for cost in [CostModel::ieee754_f32(), CostModel::paper_calibrated()] {
        println!("\n## cost model: {}", cost.name);
        println!(
            "{:>9} {:>11} {:>10} {:>9} {:>10} {:>9}",
            "rounding", "power_sav%", "area_sav%", "ops_sav%", "accuracy%", "acc_drop"
        );
        for row in &rows {
            let s = savings_report(&cost, baseline, row);
            let acc = accuracy(&model, &ds, n, row.rounding);
            println!(
                "{:>9} {:>11.2} {:>10.2} {:>9.2} {:>10.2} {:>9.2}",
                row.rounding,
                s.power_saving_pct,
                s.area_saving_pct,
                s.ops_saving_pct,
                acc * 100.0,
                (base_acc - acc) * 100.0
            );
        }
    }

    // Delay side-check: the modified unit shouldn't lengthen the schedule.
    println!("\n## PE-array schedule (16 MAC lanes + 8 sub lanes @ 1 GHz)");
    let sim = PeArraySim::new(PeArrayConfig::default());
    println!("{:>9} {:>12} {:>12} {:>9} {:>9}", "rounding", "cycles", "latency_us", "mac_util", "sub_util");
    for &r in &[0.0f32, 0.05, 0.3] {
        let infos = model.conv_layers(&[1, 1, 32, 32]);
        let pairings: Vec<(LayerPairing, usize)> = infos
            .iter()
            .map(|i| (LayerPairing::from_weights(&i.weight, r), i.out_positions))
            .collect();
        let refs: Vec<(&LayerPairing, usize)> = pairings.iter().map(|(p, n)| (p, *n)).collect();
        let rep = sim.simulate_model(&refs);
        println!(
            "{:>9} {:>12} {:>12.1} {:>9.3} {:>9.3}",
            r, rep.cycles, rep.latency_us, rep.mac_utilization, rep.sub_utilization
        );
    }
}

fn accuracy(model: &subaccel::nn::Model, ds: &subaccel::data::Dataset, n: usize, rounding: f32) -> f64 {
    let mut m = model.clone();
    if rounding > 0.0 {
        for info in model.conv_layers(&[1, 1, 32, 32]) {
            let p = LayerPairing::from_weights(&info.weight, rounding);
            m.set_conv_weights(&info.name, p.modified_weights(&info.weight));
        }
    }
    let hits = (0..n)
        .filter(|&i| m.infer(&ds.image32(i)).argmax_rows()[0] == ds.labels[i] as usize)
        .count();
    hits as f64 / n as f64
}
