//! Generality bench: the paper evaluates LeNet-5 only. This harness runs
//! Algorithm 1 over AlexNet and a VGG-style network and reports the
//! pairable fraction and projected datapath savings per rounding size —
//! the evidence that the technique transfers to larger conv nets (whose
//! weight distributions are likewise zero-centred and near-symmetric).
//!
//! Run: `cargo bench --bench generality_models`

use subaccel::accel::{model_ops, WeightStats};
use subaccel::hw::{savings_report, CostModel};
use subaccel::nn::{alexnet, grouped_mixer, lenet5, vgg_small, Model};
use subaccel::util::bench_smoke;

fn main() {
    let cost = CostModel::ieee754_f32();
    // grouped_mixer exercises the geometry LeNet/VGG/AlexNet don't:
    // grouped convs, non-square kernels, asymmetric padding, padded pool
    let nets: [(Model, &[usize]); 4] = [
        (lenet5(), &[1, 1, 32, 32]),
        (vgg_small(), &[1, 3, 32, 32]),
        (alexnet(), &[1, 3, 227, 227]),
        (grouped_mixer(), &[1, 8, 20, 16]),
    ];
    for (model, input) in &nets {
        let infos = model.conv_layers(input);
        let all: Vec<f32> = infos.iter().flat_map(|i| i.weight.data().to_vec()).collect();
        let stats = WeightStats::compute(&all);
        println!(
            "\n# {} — {} conv weights, {:.1}% max pairable (pos/neg balance)",
            model.name,
            stats.n,
            100.0 * stats.max_pairable_frac
        );
        println!(
            "{:>9} {:>14} {:>14} {:>12} {:>11}",
            "rounding", "macs", "subs", "power_sav%", "area_sav%"
        );
        let base = model_ops(model, input, 0.0);
        let roundings: &[f32] = if bench_smoke() { &[0.05] } else { &[0.001, 0.005, 0.02, 0.05] };
        for &r in roundings {
            let row = model_ops(model, input, r);
            let s = savings_report(&cost, &base, &row);
            println!(
                "{:>9} {:>14} {:>14} {:>12.2} {:>11.2}",
                r, row.muls, row.subs, s.power_saving_pct, s.area_saving_pct
            );
        }
    }
    println!(
        "\nNote: AlexNet/VGG weights here are seeded random init — pairing\n\
         statistics depend on the distribution shape (zero-centred,\n\
         near-symmetric), which trained nets share; LeNet-5 rows use the\n\
         trained distribution elsewhere in this repo and agree."
    );
}
