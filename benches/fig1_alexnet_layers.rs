//! Bench: regenerate **Fig 1** — AlexNet inference computational time
//! share per layer — on the pure-rust engine, alongside the MAC-count
//! model (the "GPU" proxy: a massively parallel device tracks op counts
//! rather than cache behaviour).
//!
//! Run: `cargo bench --bench fig1_alexnet_layers`
//!
//! Expected shape (paper): convolutional layers ≈ 90 % of inference time.
//! A second section times each conv layer on the paired subtractor
//! engine, serial vs multi-threaded — the layers Fig 1 says dominate are
//! exactly the ones the engine shards.

use subaccel::accel::{ConvEngine, SubConv2d};
use subaccel::nn::{alexnet, LayerKind};
use subaccel::tensor::Tensor;
use subaccel::util::{bench, bench_header, bench_smoke};

fn main() {
    let m = alexnet();
    let x = Tensor::zeros(&[1, 3, 227, 227]);
    let reps = if bench_smoke() { 1 } else { 3 };

    let mut acc: Vec<(String, f64, u64)> = Vec::new();
    for _ in 0..reps {
        for (i, (name, secs, counts)) in m.profile(&x).into_iter().enumerate() {
            if acc.len() <= i {
                acc.push((name, 0.0, counts.muls));
            }
            acc[i].1 += secs;
        }
    }
    let total_t: f64 = acc.iter().map(|(_, t, _)| *t).sum();
    let total_m: u64 = acc.iter().map(|(_, _, c)| *c).sum();

    println!("# Fig 1 — AlexNet per-layer share ({reps} reps)");
    println!(
        "{:>8} {:>10} {:>9} {:>15} {:>9}  {}",
        "layer", "time_ms", "cpu_%", "macs", "mac_%", "bar(cpu)"
    );
    for (name, t, macs) in &acc {
        let cpu_pct = 100.0 * t / total_t;
        let mac_pct = 100.0 * *macs as f64 / total_m as f64;
        let bar = "#".repeat((cpu_pct / 2.0) as usize);
        println!(
            "{:>8} {:>10.2} {:>9.2} {:>15} {:>9.2}  {bar}",
            name,
            t * 1e3 / reps as f64,
            cpu_pct,
            macs,
            mac_pct
        );
    }
    let conv_t: f64 = acc.iter().filter(|(n, ..)| n.starts_with("conv")).map(|(_, t, _)| *t).sum();
    let conv_m: u64 = acc.iter().filter(|(n, ..)| n.starts_with("conv")).map(|(_, _, c)| *c).sum();
    println!(
        "\nconv share: {:.1}% of CPU time, {:.1}% of MACs (paper Fig 1: ~90% on CPU and GPU)",
        100.0 * conv_t / total_t,
        100.0 * conv_m as f64 / total_m as f64
    );

    // --- the dominant layers on the paired engine, serial vs parallel ----
    let n_threads = ConvEngine::host_threads();
    let engine = ConvEngine::new(n_threads).expect("engine");
    println!("\n# per-conv-layer paired engine (rounding 0.05), serial vs {n_threads} threads");
    println!("{}", bench_header());
    let mut h = x.clone();
    for layer in &m.layers {
        if let LayerKind::Conv2d { weight, bias, stride, pad } = &layer.kind {
            let unit = SubConv2d::compile_geo(weight, bias, 0.05, *stride, *pad);
            let serial = bench(&format!("{} serial", layer.name), 1, 5, || {
                unit.forward(&h).0.len()
            });
            println!("{}", serial.report());
            let par = bench(&format!("{} engine t={n_threads}", layer.name), 1, 5, || {
                unit.forward_with(&engine, &h).unwrap().0.len()
            });
            println!(
                "{}  [{:.2}x]",
                par.report(),
                serial.mean.as_secs_f64() / par.mean.as_secs_f64()
            );
        }
        h = layer.forward(&h).0;
    }
}
