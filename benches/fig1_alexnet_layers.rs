//! Bench: regenerate **Fig 1** — AlexNet inference computational time
//! share per layer — on the pure-rust engine, alongside the MAC-count
//! model (the "GPU" proxy: a massively parallel device tracks op counts
//! rather than cache behaviour).
//!
//! Run: `cargo bench --bench fig1_alexnet_layers`
//!
//! Expected shape (paper): convolutional layers ≈ 90 % of inference time.
//! A second section profiles the paired path through the same plan-level
//! instrumentation ([`PlanExecutor::profile`] via
//! `PairedModel::profile_with`), serial vs multi-threaded — the layers
//! Fig 1 says dominate are exactly the ones the engine shards.

use subaccel::accel::ConvEngine;
use subaccel::nn::{alexnet, PairedModel};
use subaccel::tensor::Tensor;
use subaccel::util::bench_smoke;

fn main() {
    let m = alexnet();
    let x = Tensor::zeros(&[1, 3, 227, 227]);
    let reps = if bench_smoke() { 1 } else { 3 };

    let mut acc: Vec<(String, f64, u64)> = Vec::new();
    for _ in 0..reps {
        for (i, (name, secs, counts)) in m.profile(&x).into_iter().enumerate() {
            if acc.len() <= i {
                acc.push((name, 0.0, counts.muls));
            }
            acc[i].1 += secs;
        }
    }
    let total_t: f64 = acc.iter().map(|(_, t, _)| *t).sum();
    let total_m: u64 = acc.iter().map(|(_, _, c)| *c).sum();

    println!("# Fig 1 — AlexNet per-layer share ({reps} reps)");
    println!(
        "{:>8} {:>10} {:>9} {:>15} {:>9}  {}",
        "layer", "time_ms", "cpu_%", "macs", "mac_%", "bar(cpu)"
    );
    for (name, t, macs) in &acc {
        let cpu_pct = 100.0 * t / total_t;
        let mac_pct = 100.0 * *macs as f64 / total_m as f64;
        let bar = "#".repeat((cpu_pct / 2.0) as usize);
        println!(
            "{:>8} {:>10.2} {:>9.2} {:>15} {:>9.2}  {bar}",
            name,
            t * 1e3 / reps as f64,
            cpu_pct,
            macs,
            mac_pct
        );
    }
    let conv_t: f64 = acc.iter().filter(|(n, ..)| n.starts_with("conv")).map(|(_, t, _)| *t).sum();
    let conv_m: u64 = acc.iter().filter(|(n, ..)| n.starts_with("conv")).map(|(_, _, c)| *c).sum();
    println!(
        "\nconv share: {:.1}% of CPU time, {:.1}% of MACs (paper Fig 1: ~90% on CPU and GPU)",
        100.0 * conv_t / total_t,
        100.0 * conv_m as f64 / total_m as f64
    );

    // --- the paired path, per step through the plan profiler -------------
    // Same per-step instrumentation as the dense profile above:
    // PairedModel::profile_with routes through PlanExecutor::profile, so
    // both columns name the same steps and carry the same static counts.
    let n_threads = ConvEngine::host_threads();
    let serial = ConvEngine::serial();
    let engine = ConvEngine::new(n_threads).expect("engine");
    let pm = PairedModel::compile(&m, 0.05);
    println!("\n# paired plan profile (rounding 0.05), serial vs {n_threads} threads");
    println!("{:>8} {:>12} {:>12} {:>8} {:>12}", "step", "serial_ms", "par_ms", "speedup", "subs");
    let p1 = pm.profile_with(&serial, &x).expect("paired profile (serial)");
    let pn = pm.profile_with(&engine, &x).expect("paired profile (parallel)");
    for ((name, t1, counts), (_, tn, _)) in p1.iter().zip(&pn) {
        println!(
            "{:>8} {:>12.2} {:>12.2} {:>7.2}x {:>12}",
            name,
            t1 * 1e3,
            tn * 1e3,
            t1 / tn.max(1e-9),
            counts.subs
        );
    }
    let conv1: f64 = p1.iter().filter(|(n, ..)| n.starts_with("conv")).map(|(_, t, _)| *t).sum();
    let convn: f64 = pn.iter().filter(|(n, ..)| n.starts_with("conv")).map(|(_, t, _)| *t).sum();
    println!(
        "conv total: serial {:.1} ms vs t={n_threads} {:.1} ms ({:.2}x)",
        conv1 * 1e3,
        convn * 1e3,
        conv1 / convn.max(1e-9)
    );
}
