//! Ablation bench: the paper's two-pointer pairing vs closest-gap-first
//! matching, per rounding size — pairs found, total snap error, and
//! end accuracy. Answers "is the greedy walk the right design choice?"
//! (DESIGN.md §5, Fig 5/6 implementation detail).
//!
//! Run: `cargo bench --bench ablation_matching`

use subaccel::accel::{
    pair_filter, pair_filter_closest_first, total_snap_error, LayerPairing,
};
use subaccel::data::{load_dataset, load_weights};
use subaccel::nn::lenet5_from_params;
use subaccel::tensor::Tensor;
use subaccel::util::bench_smoke;

fn main() {
    let Ok(weights) = load_weights("artifacts/weights.bin") else {
        println!("SKIP: run `make artifacts` first");
        return;
    };
    let ds = load_dataset("artifacts/dataset.bin").expect("dataset");
    let model = lenet5_from_params(&weights);
    let infos = model.conv_layers(&[1, 1, 32, 32]);
    let n = if bench_smoke() { 20 } else { 300 }.min(ds.n);

    println!("# pairing-policy ablation (two-pointer = paper Algorithm 1)");
    println!(
        "{:>9} {:>7} {:>12} {:>10} | {:>7} {:>12} {:>10}",
        "", "2-ptr", "", "", "closest", "", ""
    );
    println!(
        "{:>9} {:>7} {:>12} {:>10} | {:>7} {:>12} {:>10}",
        "rounding", "pairs", "snap_err", "accuracy%", "pairs", "snap_err", "accuracy%"
    );
    for rounding in [0.005f32, 0.02, 0.05, 0.1, 0.2] {
        let mut stats = Vec::new();
        for closest in [false, true] {
            let mut m = model.clone();
            let mut pairs = 0usize;
            let mut err = 0.0f64;
            for info in &infos {
                let cout = info.weight.shape()[0];
                let klen = info.weight.len() / cout;
                // build per-filter pairings with the selected policy
                let mut lp = LayerPairing {
                    filters: Vec::new(),
                    k_len: klen,
                    shape: info.weight.shape().to_vec(),
                    rounding,
                };
                for c in 0..cout {
                    let fw = &info.weight.data()[c * klen..(c + 1) * klen];
                    let p = if closest {
                        pair_filter_closest_first(fw, rounding)
                    } else {
                        pair_filter(fw, rounding)
                    };
                    pairs += p.n_pairs();
                    err += total_snap_error(fw, &p);
                    lp.filters.push(p);
                }
                m.set_conv_weights(&info.name, lp.modified_weights(&info.weight));
            }
            let hits = (0..n)
                .filter(|&i| {
                    m.infer(&ds.image32(i)).argmax_rows()[0] == ds.labels[i] as usize
                })
                .count();
            stats.push((pairs, err, 100.0 * hits as f64 / n as f64));
        }
        println!(
            "{:>9} {:>7} {:>12.3} {:>10.2} | {:>7} {:>12.3} {:>10.2}",
            rounding, stats[0].0, stats[0].1, stats[0].2, stats[1].0, stats[1].1, stats[1].2
        );
    }

    // micro-cost of each policy (offline step, but worth knowing)
    let w: Vec<f32> = {
        let mut rng = subaccel::util::Rng::seed_from_u64(1);
        rng.vec_range(2400, -0.3, 0.3)
    };
    let t = Tensor::new(&[16, 150], w.clone());
    let _ = &t;
    println!("\n# policy cost on a 2400-weight layer (16 filters × 150)");
    println!("{}", subaccel::util::bench_header());
    let r1 = subaccel::util::bench("two-pointer (paper)", 3, 30, || {
        (0..16).map(|c| pair_filter(&w[c * 150..(c + 1) * 150], 0.05).n_pairs()).sum::<usize>()
    });
    println!("{}", r1.report());
    let r2 = subaccel::util::bench("closest-gap-first", 3, 30, || {
        (0..16)
            .map(|c| pair_filter_closest_first(&w[c * 150..(c + 1) * 150], 0.05).n_pairs())
            .sum::<usize>()
    });
    println!("{}", r2.report());
}
