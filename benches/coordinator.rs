//! Bench: serving-path overhead and throughput — coordinator (dynamic
//! batching) vs raw executor calls, across batch sizes, backends, and
//! offered concurrency. This quantifies the L3 §Perf target: the
//! coordinator must not be the bottleneck (<10 % overhead at saturation).
//!
//! Run: `cargo bench --bench coordinator`

use std::sync::Arc;
use std::time::{Duration, Instant};
use subaccel::coordinator::{Backend, Coordinator, ServeConfig};
use subaccel::data::{load_dataset, load_weights};
use subaccel::runtime::{LeNet5Executor, Runtime, Variant};
use subaccel::util::bench_smoke;

fn main() {
    let smoke = bench_smoke();
    let Ok(weights) = load_weights("artifacts/weights.bin") else {
        println!("SKIP: run `make artifacts` first");
        return;
    };
    let ds = Arc::new(load_dataset("artifacts/dataset.bin").expect("dataset"));

    // --- raw executor baseline ------------------------------------------
    println!("# raw executor (no coordinator), xla-native artifact");
    let rt = Runtime::cpu().expect("PJRT client");
    for batch in [1usize, 8, 32] {
        let exe = LeNet5Executor::load(&rt, "artifacts", Variant::XlaNative, batch, &weights)
            .expect("load artifact");
        let input = ds.batch32(0, batch);
        // warmup
        for _ in 0..if smoke { 1 } else { 3 } {
            exe.execute(&input).unwrap();
        }
        let iters = if smoke { 1 } else { 200 / batch.max(1) + 10 };
        let t0 = Instant::now();
        for _ in 0..iters {
            exe.execute(&input).unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "  b{batch:<3} {:>10.2} ms/batch  {:>9.1} img/s",
            dt.as_secs_f64() * 1e3 / iters as f64,
            (iters * batch) as f64 / dt.as_secs_f64()
        );
    }

    // --- coordinator under offered load ----------------------------------
    for (backend, bname, batches) in [
        (Backend::Pjrt(Variant::XlaNative), "xla-native", &[8usize, 32][..]),
        (Backend::CpuEngine, "cpu-engine", &[8usize][..]),
    ] {
        println!("\n# coordinator (dynamic batching), {bname} backend");
        println!(
            "{:>6} {:>8} {:>10} {:>11} {:>10} {:>10} {:>10}",
            "batch", "clients", "req/s", "mean_batch", "e2e_p50", "e2e_p99", "exec_mean"
        );
        for &batch in batches {
            for clients in if smoke { &[8usize][..] } else { &[1usize, 8, 64][..] } {
                let clients = *clients;
                let cfg = ServeConfig::builder()
                    .artifacts_dir("artifacts")
                    .backend(backend)
                    .batch_size(batch)
                    .max_wait(Duration::from_millis(2))
                    .build()
                    .expect("bench config");
                let coord = Arc::new(Coordinator::start(cfg).expect("start"));
                let per_client = if smoke { 16 } else { 400 } / clients;
                let t0 = Instant::now();
                let handles: Vec<_> = (0..clients)
                    .map(|c| {
                        let coord = coord.clone();
                        let ds = ds.clone();
                        std::thread::spawn(move || {
                            for i in 0..per_client {
                                let idx = (c * per_client + i) % ds.n;
                                while coord.classify(ds.image32(idx)).is_err() {
                                    std::thread::sleep(Duration::from_micros(100));
                                }
                            }
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                let dt = t0.elapsed();
                let snap = coord.metrics().snapshot();
                println!(
                    "{:>6} {:>8} {:>10.1} {:>11.2} {:>9}µs {:>9}µs {:>9.0}µs",
                    batch,
                    clients,
                    (clients * per_client) as f64 / dt.as_secs_f64(),
                    snap.mean_batch_size,
                    snap.e2e.p50_us,
                    snap.e2e.p99_us,
                    snap.execute.mean_us,
                );
            }
        }
    }
}
