//! Extension bench: what the paper's Fig 8 looks like at *system level*
//! (datapath + memory traffic) and in the *int8* domain.
//!
//! Run: `cargo bench --bench system_energy`
//!
//! Two honest caveats to the paper this quantifies:
//! 1. Including weight/activation movement (SRAM+DRAM) shrinks the
//!    relative saving — input traffic is untouched by the method.
//! 2. Int8 units have a *higher* mul/add cost ratio, so the datapath
//!    saving grows; int8 accuracy through the quantized paired unit is
//!    also reported.

use subaccel::accel::{model_op_sweep, LayerPairing, TABLE1_ROUNDINGS};
use subaccel::data::{load_dataset, load_weights};
use subaccel::hw::{
    savings_report, system_energy_opt, CostModel, LayerGeometry, MemoryModel, QuantSubConv2d,
};
use subaccel::nn::layers::{avgpool2, dense_layer, tanh_inplace};
use subaccel::nn::lenet5_from_params;
use subaccel::tensor::Tensor;
use subaccel::util::bench_smoke;

fn main() {
    let Ok(weights) = load_weights("artifacts/weights.bin") else {
        println!("SKIP: run `make artifacts` first");
        return;
    };
    let ds = load_dataset("artifacts/dataset.bin").expect("dataset");
    let model = lenet5_from_params(&weights);
    let infos = model.conv_layers(&[1, 1, 32, 32]);
    let cost = CostModel::ieee754_f32();
    let mem = MemoryModel::horowitz_45nm();

    // geometry per conv layer (single inference)
    let geos = [
        LayerGeometry { ifmap_words: 1 * 32 * 32, ofmap_words: 6 * 28 * 28, out_positions: 784 },
        LayerGeometry { ifmap_words: 6 * 14 * 14, ofmap_words: 16 * 10 * 10, out_positions: 100 },
        LayerGeometry { ifmap_words: 16 * 5 * 5, ofmap_words: 120, out_positions: 1 },
    ];

    println!("# system-level energy (datapath + SRAM/DRAM traffic, f32)");
    println!(
        "{:>9} {:>14} {:>15} {:>16} {:>15}",
        "rounding", "datapath_sav%", "sys_sav%(res.)", "sys_sav%(stream)", "dense_nJ(res.)"
    );
    let rows = model_op_sweep(&model, &[1, 1, 32, 32], &TABLE1_ROUNDINGS);
    for (row, &r) in rows.iter().zip(TABLE1_ROUNDINGS.iter()) {
        let mut e = [[0.0f64; 2]; 2]; // [dense|paired][resident|streamed]
        for (info, geo) in infos.iter().zip(geos.iter()) {
            let p = LayerPairing::from_weights(&info.weight, r);
            for (di, dense) in [true, false].iter().enumerate() {
                for (ri, resident) in [true, false].iter().enumerate() {
                    e[di][ri] += system_energy_opt(&cost, &mem, &p, *geo, *dense, *resident);
                }
            }
        }
        let dp = savings_report(&cost, &rows[0], row);
        println!(
            "{:>9} {:>14.2} {:>15.2} {:>16.2} {:>15.1}",
            r,
            dp.power_saving_pct,
            (1.0 - e[1][0] / e[0][0]) * 100.0,
            (1.0 - e[1][1] / e[0][1]) * 100.0,
            e[0][0] * 1e-3,
        );
    }

    // ---- int8 domain ------------------------------------------------------
    let int8 = CostModel::int8();
    println!("\n# int8 datapath savings + quantized-paired-unit accuracy");
    println!(
        "{:>9} {:>12} {:>11} {:>14}",
        "rounding", "power_sav%", "area_sav%", "int8_accuracy%"
    );
    let n = if bench_smoke() { 20 } else { 200 }.min(ds.n);
    for &r in &[0.0f32, 0.01, 0.05, 0.1, 0.2] {
        let row = rows
            .iter()
            .find(|x| (x.rounding - r).abs() < 1e-9)
            .expect("rounding in table");
        let s = savings_report(&int8, &rows[0], row);
        let units: Vec<QuantSubConv2d> = infos
            .iter()
            .map(|i| QuantSubConv2d::compile(&i.weight, &i.bias, r))
            .collect();
        let hits = (0..n)
            .filter(|&i| quant_forward(&weights, &units, &ds.image32(i)) == ds.labels[i] as usize)
            .count();
        println!(
            "{:>9} {:>12.2} {:>11.2} {:>14.2}",
            r,
            s.power_saving_pct,
            s.area_saving_pct,
            100.0 * hits as f64 / n as f64
        );
    }
}

/// LeNet-5 forward with conv layers on the int8 paired unit.
fn quant_forward(
    weights: &std::collections::HashMap<String, Tensor>,
    units: &[QuantSubConv2d],
    x: &Tensor,
) -> usize {
    let mut h = x.clone();
    for (i, unit) in units.iter().enumerate() {
        let (mut out, _) = unit.forward(&h);
        tanh_inplace(&mut out);
        h = out;
        if i < 2 {
            h = avgpool2(&h);
        }
    }
    let b = h.shape()[0];
    h = h.reshape(&[b, 120]);
    let mut f6 = dense_layer(&h, &weights["f6_w"], &weights["f6_b"]);
    tanh_inplace(&mut f6);
    dense_layer(&f6, &weights["out_w"], &weights["out_b"]).argmax_rows()[0]
}
