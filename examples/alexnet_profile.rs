//! Fig-1 reproduction: AlexNet per-layer inference-time share on the
//! pure-rust engine, plus the what-if: op counts if the subtractor
//! preprocessor were applied to AlexNet's conv layers (the paper's
//! motivation is exactly that conv dominates, so savings there dominate).
//!
//! Run: `cargo run --release --example alexnet_profile`

use anyhow::Result;
use subaccel::accel::LayerPairing;
use subaccel::nn::alexnet;
use subaccel::tensor::Tensor;

fn main() -> Result<()> {
    let m = alexnet();
    let x = Tensor::zeros(&[1, 3, 227, 227]);

    println!("profiling AlexNet (1 image, 227×227×3)...");
    let profile = m.profile(&x);
    let total: f64 = profile.iter().map(|(_, t, _)| *t).sum();

    println!("\n# Fig 1 — per-layer share of inference time");
    println!("{:>8} {:>10} {:>8}  bar", "layer", "time_ms", "share%");
    for (name, t, _) in &profile {
        let pct = 100.0 * t / total;
        println!("{:>8} {:>10.2} {:>8.2}  {}", name, t * 1e3, pct, "#".repeat((pct / 2.0) as usize));
    }
    let conv: f64 = profile.iter().filter(|(n, ..)| n.starts_with("conv")).map(|(_, t, _)| *t).sum();
    println!("\nconv layers: {:.1}% of total (paper: ~90% on CPU/GPU)", 100.0 * conv / total);

    // what-if: pairing applied to AlexNet conv weights (random init here —
    // trained AlexNet weights are also near-symmetric around 0)
    println!("\n# what-if — Algorithm 1 on AlexNet conv layers at rounding 0.01");
    let infos = m.conv_layers(&[1, 3, 227, 227]);
    let mut total_macs = 0u64;
    let mut total_pairs = 0u64;
    for info in &infos {
        let p = LayerPairing::from_weights(&info.weight, 0.01);
        let macs = (info.weight.len() * info.out_positions) as u64;
        let pairs = (p.total_pairs() * info.out_positions) as u64;
        total_macs += macs;
        total_pairs += pairs;
        println!(
            "  {:>6}: {:>11} MACs, {:>10} paired/pos-weighted ({:>5.1}%)",
            info.name,
            macs,
            pairs,
            100.0 * pairs as f64 / macs as f64
        );
    }
    println!(
        "\ntotal: {:.1}% of AlexNet conv MACs pairable at 0.01 → proportional power/area wins",
        100.0 * total_pairs as f64 / total_macs as f64
    );
    Ok(())
}
