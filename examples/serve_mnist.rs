//! End-to-end serving demo (the DESIGN.md validation driver): start the
//! coordinator over the AOT LeNet-5 artifact, fire concurrent client
//! load, switch rounding variants live, and report accuracy + latency +
//! throughput per variant.
//!
//! Run: `cargo run --release --example serve_mnist` (after `make artifacts`)

use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};
use subaccel::coordinator::{Coordinator, ServeConfig};
use subaccel::data::load_dataset;
use subaccel::runtime::Variant;

const REQUESTS: usize = 512;
const CLIENTS: usize = 16;

fn main() -> Result<()> {
    let ds = Arc::new(load_dataset("artifacts/dataset.bin").context("run `make artifacts`")?);
    let cfg = ServeConfig::builder()
        .artifacts_dir("artifacts")
        .variant(Variant::XlaNative)
        .batch_size(8)
        .max_wait(Duration::from_millis(2))
        .queue_cap(1024)
        .workers(1)
        .build()?;
    println!("starting coordinator (xla-native artifact, batch {})", cfg.batch_size());
    let coord = Arc::new(Coordinator::start(cfg)?);

    // serve the paper's interesting rounding points, switching live
    for rounding in [0.0f32, 0.05, 0.3] {
        let pairs = coord.set_rounding(rounding)?;
        let t0 = Instant::now();
        let per_client = REQUESTS / CLIENTS;
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let coord = coord.clone();
                let ds = ds.clone();
                std::thread::spawn(move || {
                    let mut hits = 0usize;
                    for i in 0..per_client {
                        let idx = (c * per_client + i) % ds.n;
                        loop {
                            match coord.classify(ds.image32(idx)) {
                                Ok(logits) => {
                                    let pred = logits
                                        .iter()
                                        .enumerate()
                                        .max_by(|a, b| a.1.total_cmp(b.1))
                                        .map(|(j, _)| j)
                                        .unwrap();
                                    hits += (pred == ds.labels[idx] as usize) as usize;
                                    break;
                                }
                                Err(_) => std::thread::sleep(Duration::from_micros(200)),
                            }
                        }
                    }
                    hits
                })
            })
            .collect();
        let hits: usize = handles.into_iter().map(|h| h.join().unwrap_or(0)).sum();
        let dt = t0.elapsed();
        let snap = coord.metrics().snapshot();
        println!(
            "\nrounding {rounding:<5} ({pairs:>5} pairs): {:>6.1} req/s, accuracy {:>6.2}%",
            REQUESTS as f64 / dt.as_secs_f64(),
            100.0 * hits as f64 / REQUESTS as f64,
        );
        println!("  {snap}");
        println!(
            "  completed {} / rejected {} / mean batch {:.2} / e2e p99 {}us",
            snap.completed, snap.rejected, snap.mean_batch_size, snap.e2e.p99_us
        );
    }

    match Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown(),
        Err(_) => unreachable!("all clients joined"),
    }
    println!("\ndone.");
    Ok(())
}
