//! Quickstart: the paper's pipeline end to end on a handful of images.
//!
//! 1. Load the trained LeNet-5 (`artifacts/weights.bin`).
//! 2. Run Algorithm 1 at rounding 0.05 (the paper's headline point).
//! 3. Show what it bought: pairs found, op counts, power/area savings.
//! 4. Classify test images on the *paired subtractor datapath* and on the
//!    original dense weights, and compare.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use anyhow::{Context, Result};
use subaccel::accel::{model_ops, LayerPairing, SubConv2d};
use subaccel::data::{load_dataset, load_weights};
use subaccel::hw::{savings_report, CostModel};
use subaccel::nn::layers::{avgpool2, dense_layer, tanh_inplace};
use subaccel::nn::lenet5_from_params;
use subaccel::tensor::Tensor;

const ROUNDING: f32 = 0.05;

fn main() -> Result<()> {
    let weights = load_weights("artifacts/weights.bin").context("run `make artifacts` first")?;
    let ds = load_dataset("artifacts/dataset.bin")?;
    let model = lenet5_from_params(&weights);

    // --- 2. preprocess -----------------------------------------------------
    println!("== Algorithm 1 at rounding {ROUNDING} ==");
    let infos = model.conv_layers(&[1, 1, 32, 32]);
    let mut units = Vec::new();
    for info in &infos {
        let pairing = LayerPairing::from_weights(&info.weight, ROUNDING);
        println!(
            "  {}: {:>5} weights → {:>4} pairs ({:>5.1}% combined), max snap err {:.5}",
            info.name,
            info.weight.len(),
            pairing.total_pairs(),
            200.0 * pairing.total_pairs() as f32 / info.weight.len() as f32,
            pairing.max_snap_error(&info.weight),
        );
        units.push(SubConv2d::compile(&info.weight, &info.bias, ROUNDING));
    }

    // --- 3. what it bought ---------------------------------------------------
    let base = model_ops(&model, &[1, 1, 32, 32], 0.0);
    let point = model_ops(&model, &[1, 1, 32, 32], ROUNDING);
    println!("\n== op counts per inference (conv layers) ==");
    println!("  dense : {} mul + {} add            = {} ops", base.muls, base.adds, base.total);
    println!(
        "  paired: {} mul + {} add + {} sub = {} ops",
        point.muls, point.adds, point.subs, point.total
    );
    let cost = CostModel::ieee754_f32();
    let s = savings_report(&cost, &base, &point);
    println!(
        "  cost model {} → power −{:.2}%, area −{:.2}%, ops −{:.2}%",
        cost.name, s.power_saving_pct, s.area_saving_pct, s.ops_saving_pct
    );

    // --- 4. classify on the paired datapath ---------------------------------
    println!("\n== classification (paired subtractor unit vs dense) ==");
    let n = 16.min(ds.n);
    let mut agree = 0;
    let mut hits = 0;
    for i in 0..n {
        let img = ds.image32(i);
        let dense_pred = model.infer(&img).argmax_rows()[0];
        let paired_pred = paired_forward(&weights, &units, &img);
        agree += (dense_pred == paired_pred) as usize;
        hits += (paired_pred == ds.labels[i] as usize) as usize;
        println!(
            "  img {i:>2}: label {}  dense→{}  paired→{}",
            ds.labels[i], dense_pred, paired_pred
        );
    }
    println!("\npaired accuracy {hits}/{n}; dense/paired agreement {agree}/{n}");
    Ok(())
}

/// LeNet-5 forward with all conv layers on the subtractor datapath.
fn paired_forward(
    weights: &std::collections::HashMap<String, Tensor>,
    units: &[SubConv2d],
    x: &Tensor,
) -> usize {
    let mut h = x.clone();
    for (i, unit) in units.iter().enumerate() {
        let (mut out, _) = unit.forward(&h);
        tanh_inplace(&mut out);
        h = out;
        if i < 2 {
            h = avgpool2(&h);
        }
    }
    let b = h.shape()[0];
    h = h.reshape(&[b, 120]);
    let mut f6 = dense_layer(&h, &weights["f6_w"], &weights["f6_b"]);
    tanh_inplace(&mut f6);
    dense_layer(&f6, &weights["out_w"], &weights["out_b"]).argmax_rows()[0]
}
