//! Quickstart: the paper's pipeline end to end on a handful of images.
//!
//! 1. Load the trained LeNet-5 (`artifacts/weights.bin`).
//! 2. Run Algorithm 1 at rounding 0.05 (the paper's headline point).
//! 3. Show what it bought: pairs found, op counts, power/area savings.
//! 4. Classify test images on the *paired subtractor datapath* (via
//!    [`PairedModel`] on a multi-threaded [`ConvEngine`]) and on the
//!    original dense weights, and compare.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use anyhow::{Context, Result};
use subaccel::accel::{model_ops, ConvEngine, LayerPairing};
use subaccel::data::{load_dataset, load_weights};
use subaccel::hw::{savings_report, CostModel};
use subaccel::nn::{lenet5_from_params, PairedModel};

const ROUNDING: f32 = 0.05;

fn main() -> Result<()> {
    let weights = load_weights("artifacts/weights.bin").context("run `make artifacts` first")?;
    let ds = load_dataset("artifacts/dataset.bin")?;
    let model = lenet5_from_params(&weights);

    // --- 2. preprocess -----------------------------------------------------
    println!("== Algorithm 1 at rounding {ROUNDING} ==");
    let infos = model.conv_layers(&[1, 1, 32, 32]);
    for info in &infos {
        let pairing = LayerPairing::from_weights(&info.weight, ROUNDING);
        println!(
            "  {}: {:>5} weights → {:>4} pairs ({:>5.1}% combined), max snap err {:.5}",
            info.name,
            info.weight.len(),
            pairing.total_pairs(),
            200.0 * pairing.total_pairs() as f32 / info.weight.len() as f32,
            pairing.max_snap_error(&info.weight),
        );
    }
    let paired = PairedModel::compile(&model, ROUNDING);
    let engine = ConvEngine::new(ConvEngine::host_threads())?;
    println!(
        "compiled `{}`: {} total pairs, engine threads {}",
        paired.name(),
        paired.total_pairs(),
        engine.threads()
    );

    // --- 3. what it bought ---------------------------------------------------
    let base = model_ops(&model, &[1, 1, 32, 32], 0.0);
    let point = model_ops(&model, &[1, 1, 32, 32], ROUNDING);
    println!("\n== op counts per inference (conv layers) ==");
    println!("  dense : {} mul + {} add            = {} ops", base.muls, base.adds, base.total);
    println!(
        "  paired: {} mul + {} add + {} sub = {} ops",
        point.muls, point.adds, point.subs, point.total
    );
    let cost = CostModel::ieee754_f32();
    let s = savings_report(&cost, &base, &point);
    println!(
        "  cost model {} → power −{:.2}%, area −{:.2}%, ops −{:.2}%",
        cost.name, s.power_saving_pct, s.area_saving_pct, s.ops_saving_pct
    );

    // --- 4. classify on the paired datapath ---------------------------------
    println!("\n== classification (paired subtractor unit vs dense) ==");
    let n = 16.min(ds.n);
    let mut agree = 0;
    let mut hits = 0;
    for i in 0..n {
        let img = ds.image32(i);
        let dense_pred = model.infer(&img).argmax_rows()[0];
        let paired_pred = paired.infer_with(&engine, &img)?.argmax_rows()[0];
        agree += (dense_pred == paired_pred) as usize;
        hits += (paired_pred == ds.labels[i] as usize) as usize;
        println!(
            "  img {i:>2}: label {}  dense→{}  paired→{}",
            ds.labels[i], dense_pred, paired_pred
        );
    }
    println!("\npaired accuracy {hits}/{n}; dense/paired agreement {agree}/{n}");
    Ok(())
}
