//! Full rounding-size sweep: regenerates Table 1, Fig 7 (ASCII bar
//! chart of the op mix) and Fig 8 (accuracy/power/area trade-off) in one
//! run, and writes CSVs to `artifacts/results/` for external plotting.
//!
//! Run: `cargo run --release --example rounding_sweep`

use anyhow::{Context, Result};
use std::fmt::Write as _;
use subaccel::accel::{model_op_sweep, LayerPairing, TABLE1_ROUNDINGS};
use subaccel::data::{load_dataset, load_weights};
use subaccel::hw::{savings_report, CostModel};
use subaccel::nn::lenet5_from_params;

fn main() -> Result<()> {
    let weights = load_weights("artifacts/weights.bin").context("run `make artifacts`")?;
    let ds = load_dataset("artifacts/dataset.bin")?;
    let model = lenet5_from_params(&weights);
    let rows = model_op_sweep(&model, &[1, 1, 32, 32], &TABLE1_ROUNDINGS);
    std::fs::create_dir_all("artifacts/results")?;

    // ---- Table 1 ---------------------------------------------------------
    println!("# Table 1 — op counts per rounding size");
    println!(
        "{:>9} {:>10} {:>13} {:>16} {:>9}",
        "rounding", "additions", "subtractions", "multiplications", "total"
    );
    let mut csv = String::from("rounding,additions,subtractions,multiplications,total\n");
    for r in &rows {
        println!(
            "{:>9} {:>10} {:>13} {:>16} {:>9}",
            r.rounding, r.adds, r.subs, r.muls, r.total
        );
        writeln!(csv, "{},{},{},{},{}", r.rounding, r.adds, r.subs, r.muls, r.total)?;
    }
    std::fs::write("artifacts/results/table1.csv", &csv)?;

    // ---- Fig 7: op mix bar chart ------------------------------------------
    println!("\n# Fig 7 — op mix per rounding size (m=mul, a=add, s=sub; 1 char ≈ 16k ops)");
    for r in &rows {
        let scale = 16_000u64;
        println!(
            "{:>7}: {}{}{}",
            r.rounding,
            "m".repeat((r.muls / scale) as usize),
            "a".repeat((r.adds / scale) as usize),
            "s".repeat((r.subs / scale) as usize)
        );
    }

    // ---- Fig 8 -------------------------------------------------------------
    let n = 1000.min(ds.n);
    let cost = CostModel::ieee754_f32();
    let baseline = &rows[0];
    println!("\n# Fig 8 — trade-off ({n} images, {})", cost.name);
    println!(
        "{:>9} {:>11} {:>10} {:>10}",
        "rounding", "power_sav%", "area_sav%", "accuracy%"
    );
    let mut csv = String::from("rounding,power_saving_pct,area_saving_pct,ops_saving_pct,accuracy_pct\n");
    for row in &rows {
        let s = savings_report(&cost, baseline, row);
        let mut m = model.clone();
        if row.rounding > 0.0 {
            for info in model.conv_layers(&[1, 1, 32, 32]) {
                let p = LayerPairing::from_weights(&info.weight, row.rounding);
                m.set_conv_weights(&info.name, p.modified_weights(&info.weight));
            }
        }
        let hits = (0..n)
            .filter(|&i| m.infer(&ds.image32(i)).argmax_rows()[0] == ds.labels[i] as usize)
            .count();
        let acc = 100.0 * hits as f64 / n as f64;
        println!(
            "{:>9} {:>11.2} {:>10.2} {:>10.2}",
            row.rounding, s.power_saving_pct, s.area_saving_pct, acc
        );
        writeln!(
            csv,
            "{},{:.4},{:.4},{:.4},{:.4}",
            row.rounding, s.power_saving_pct, s.area_saving_pct, s.ops_saving_pct, acc
        )?;
    }
    std::fs::write("artifacts/results/fig8.csv", &csv)?;
    println!("\nwrote artifacts/results/{{table1,fig8}}.csv");
    Ok(())
}
