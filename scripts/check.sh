#!/usr/bin/env bash
# Repo gate: build + tests + formatting + lints. Run before every push.
#
# Usage: scripts/check.sh
set -euo pipefail

cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install the rust toolchain" >&2
    echo "       (rustup.rs, or your distro's rustc+cargo packages)" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "== all checks passed =="
