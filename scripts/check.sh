#!/usr/bin/env bash
# Repo gate: build + tests + formatting + lints. Run before every push.
#
# Usage: scripts/check.sh [--smoke]
#
#   --smoke   additionally run every bench target once with
#             SUBACCEL_BENCH_SMOKE=1 (clamped to a single short iteration
#             each — exercises the bench code paths, measures nothing).
#             conv_hotpath also writes its machine-readable trajectory to
#             BENCH_8.json (SUBACCEL_BENCH_JSON); records carry a
#             "smoke":true flag marking them as shape-only data points.
#             When a previous BENCH_8.json exists it is fed back in as
#             both the autotune warm-start cache (SUBACCEL_AUTOTUNE_CACHE)
#             and the perf baseline (SUBACCEL_BENCH_BASELINE): the bench
#             runs a capped autotune sweep and fails if the chosen tile
#             regresses conv_hotpath >10% vs the recorded trajectory
#             entry (gate auto-skips when either side is smoke-mode).
set -euo pipefail

cd "$(dirname "$0")/.."

smoke=0
for arg in "$@"; do
    case "$arg" in
        --smoke) smoke=1 ;;
        *)
            echo "usage: scripts/check.sh [--smoke]" >&2
            exit 2
            ;;
    esac
done

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install the rust toolchain" >&2
    echo "       (rustup.rs, or your distro's rustc+cargo packages)" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -q -- -D warnings =="
cargo clippy -q -- -D warnings

if [ "$smoke" = 1 ]; then
    for bench in benches/*.rs; do
        name="$(basename "$bench" .rs)"
        echo "== bench smoke: $name =="
        if [ "$name" = conv_hotpath ]; then
            # env assignments go through an array + `env` because the
            # baseline/cache vars are conditional, and `${var:+X=y}` is
            # not parsed as an assignment prefix by the shell
            env_args=(SUBACCEL_BENCH_SMOKE=1 SUBACCEL_BENCH_JSON=BENCH_8.json)
            if [ -s BENCH_8.json ]; then
                # previous trajectory: warm-start the tile sweep from it
                # and gate the fresh autotuned number against it
                cp BENCH_8.json BENCH_8.prev.json
                env_args+=(SUBACCEL_BENCH_BASELINE=BENCH_8.prev.json)
                env_args+=(SUBACCEL_AUTOTUNE_CACHE=BENCH_8.prev.json)
            fi
            env "${env_args[@]}" cargo bench --bench "$name"
            rm -f BENCH_8.prev.json
            if [ ! -s BENCH_8.json ]; then
                echo "error: conv_hotpath did not emit BENCH_8.json" >&2
                exit 1
            fi
            if ! grep -q '"name":"autotune:' BENCH_8.json; then
                echo "error: BENCH_8.json has no autotune decisions" >&2
                exit 1
            fi
            echo "== bench trajectory: BENCH_8.json ($(wc -c <BENCH_8.json) bytes) =="
        else
            SUBACCEL_BENCH_SMOKE=1 cargo bench --bench "$name"
        fi
    done
fi

echo "== all checks passed =="
