"""AOT export: lower the L2 model to HLO *text* artifacts for rust/PJRT.

Interchange is HLO text, NOT ``lowered.compile().serialize()`` and NOT a
serialized HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction
ids which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all weights are *arguments*, so one artifact serves every
rounding variant — the rust coordinator feeds modified weights):

  lenet5_b{1,8,32}.hlo.txt   Pallas-kernel forward (the paper-integrated path)
  lenet5_xla_b{1,8,32}.hlo.txt  lax.conv forward (XLA-native §Perf baseline)
  subconv_c3_b1.hlo.txt      paired subtractor-form conv for layer C3 with
                             pairing tables as runtime arguments — rust
                             feeds its own Algorithm-1 output and checks
                             equivalence against the dense modified conv.
  lenet5_paired_b{1,8}.hlo.txt  the fully-paired model: EVERY conv layer in
                             subtractor form, all pairing tables runtime
                             arguments — the paper's datapath as the
                             serving artifact (rust: PairedLeNet5Executor).

Run via ``make artifacts`` (trains first if weights.bin is missing).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import subconv

BATCH_SIZES = (1, 8, 32)

# Fixed padded pairing-table sizes for the subconv artifact (layer C3:
# K = 150 weights/filter → at most 75 pairs).
C3_PMAX = 75
C3_UMAX = 150


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_specs():
    return [
        jax.ShapeDtypeStruct(model.PARAM_SHAPES[n], jnp.float32)
        for n in model.PARAM_NAMES
    ]


def lower_lenet5(batch: int, xla_native: bool) -> str:
    x = jax.ShapeDtypeStruct((batch, 1, 32, 32), jnp.float32)
    fn = model.lenet5_xla_flat if xla_native else model.lenet5_flat
    return to_hlo_text(jax.jit(fn).lower(x, *_param_specs()))


def subconv_c3_flat(x, i1, i2, pk, iu, wu, bias):
    """C3 paired conv with pairing tables as runtime args.  x: (B,6,14,14)."""
    return (subconv.subconv2d(x, i1, i2, pk, iu, wu, bias, 5, 5),)


def lower_subconv_c3(batch: int) -> str:
    cout = 16
    specs = (
        jax.ShapeDtypeStruct((batch, 6, 14, 14), jnp.float32),
        jax.ShapeDtypeStruct((cout, C3_PMAX), jnp.int32),
        jax.ShapeDtypeStruct((cout, C3_PMAX), jnp.int32),
        jax.ShapeDtypeStruct((cout, C3_PMAX), jnp.float32),
        jax.ShapeDtypeStruct((cout, C3_UMAX), jnp.int32),
        jax.ShapeDtypeStruct((cout, C3_UMAX), jnp.float32),
        jax.ShapeDtypeStruct((cout,), jnp.float32),
    )
    return to_hlo_text(jax.jit(subconv_c3_flat).lower(*specs))


def lower_paired_lenet5(batch: int) -> str:
    """Fully-paired LeNet-5: pairing tables for all conv layers are
    runtime arguments (see model.lenet5_paired_flat for the order)."""
    specs = [jax.ShapeDtypeStruct((batch, 1, 32, 32), jnp.float32)]
    for name in ("c1", "c3", "c5"):
        cout, pmax, umax = model.PAIRED_TABLE_SIZES[name]
        specs += [
            jax.ShapeDtypeStruct((cout, pmax), jnp.int32),
            jax.ShapeDtypeStruct((cout, pmax), jnp.int32),
            jax.ShapeDtypeStruct((cout, pmax), jnp.float32),
            jax.ShapeDtypeStruct((cout, umax), jnp.int32),
            jax.ShapeDtypeStruct((cout, umax), jnp.float32),
            jax.ShapeDtypeStruct((cout,), jnp.float32),
        ]
    for n in ("f6_w", "f6_b", "out_w", "out_b"):
        specs.append(jax.ShapeDtypeStruct(model.PARAM_SHAPES[n], jnp.float32))
    return to_hlo_text(jax.jit(model.lenet5_paired_flat).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    if not args.skip_train and not os.path.exists(os.path.join(outdir, "weights.bin")):
        print("weights.bin missing — training LeNet-5 (build-time, one-off)")
        from . import train as _train

        params, test_raw, xte32, yte, curve = _train.train()
        _train.export(outdir, params, test_raw, xte32, yte, curve)

    for b in BATCH_SIZES:
        for native in (False, True):
            tag = "lenet5_xla" if native else "lenet5"
            path = os.path.join(outdir, f"{tag}_b{b}.hlo.txt")
            text = lower_lenet5(b, native)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")

    path = os.path.join(outdir, "subconv_c3_b1.hlo.txt")
    text = lower_subconv_c3(1)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")

    for b in (1, 8):
        path = os.path.join(outdir, f"lenet5_paired_b{b}.hlo.txt")
        text = lower_paired_lenet5(b)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
