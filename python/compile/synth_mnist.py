"""Synthetic MNIST substitute (DESIGN.md §3 substitution table).

The image has no network access and no bundled MNIST, so we generate a
procedural handwritten-digit look-alike: 7×5 glyph bitmaps rendered onto a
28×28 canvas through a random affine map (translate / scale / rotate /
shear), stroke-thickened, blurred, and noised.  The generator is
numpy-only and fully seeded so python (training) and any future consumer
reproduce the same data.

If a real MNIST IDX directory is supplied (``--mnist DIR`` with the four
classic files), it is used instead — the rest of the pipeline is
byte-identical either way.
"""

from __future__ import annotations

import gzip
import os
import struct

import numpy as np

# 7×5 glyphs, 1 = ink. Deliberately "handwriting-ish": distinct topologies
# per digit so a small CNN has real features to learn.
_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _GLYPHS[d]], dtype=np.float32)


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Render one 28×28 image in [0, 1] via inverse-mapped bilinear affine."""
    g = _glyph_array(digit)  # (7, 5)
    gh, gw = g.shape
    # random affine: rotation, log-scale, shear, translation
    th = rng.uniform(-0.25, 0.25)  # ~±14°
    sx = np.exp(rng.uniform(-0.15, 0.15)) * 3.2  # glyph-px → canvas-px
    sy = np.exp(rng.uniform(-0.15, 0.15)) * 3.2
    sh = rng.uniform(-0.2, 0.2)
    tx = 14.0 + rng.uniform(-2.5, 2.5)
    ty = 14.0 + rng.uniform(-2.5, 2.5)
    c, s = np.cos(th), np.sin(th)
    # forward map: glyph coords (centred) → canvas
    fwd = np.array([[sx * c, -sy * (s + sh)], [sx * s, sy * c]])
    inv = np.linalg.inv(fwd)
    ys, xs = np.mgrid[0:28, 0:28].astype(np.float32)
    u = inv[0, 0] * (xs - tx) + inv[0, 1] * (ys - ty) + (gw - 1) / 2.0
    v = inv[1, 0] * (xs - tx) + inv[1, 1] * (ys - ty) + (gh - 1) / 2.0
    # bilinear sample with zero padding
    u0, v0 = np.floor(u).astype(int), np.floor(v).astype(int)
    du, dv = u - u0, v - v0

    def tap(vv, uu):
        ok = (uu >= 0) & (uu < gw) & (vv >= 0) & (vv < gh)
        return np.where(ok, g[np.clip(vv, 0, gh - 1), np.clip(uu, 0, gw - 1)], 0.0)

    img = (
        tap(v0, u0) * (1 - du) * (1 - dv)
        + tap(v0, u0 + 1) * du * (1 - dv)
        + tap(v0 + 1, u0) * (1 - du) * dv
        + tap(v0 + 1, u0 + 1) * du * dv
    )
    # stroke thickening + blur: two 3×3 box passes
    for _ in range(2):
        p = np.pad(img, 1)
        img = sum(
            p[dy : dy + 28, dx : dx + 28] for dy in range(3) for dx in range(3)
        ) / 4.5
    img = np.clip(img, 0.0, 1.0)
    img += rng.normal(0.0, 0.04, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """n images (n, 28, 28) f32 in [0,1] + labels (n,) u8, balanced classes."""
    rng = np.random.default_rng(seed)
    labels = (np.arange(n) % 10).astype(np.uint8)
    rng.shuffle(labels)
    imgs = np.stack([_render(int(d), rng) for d in labels])
    return imgs, labels


def _read_idx(path: str) -> np.ndarray:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rb") as f:
        magic = struct.unpack(">I", f.read(4))[0]
        ndim = magic & 0xFF
        dims = struct.unpack(f">{ndim}I", f.read(4 * ndim))
        return np.frombuffer(f.read(), dtype=np.uint8).reshape(dims)


def load_mnist_idx(d: str):
    """Load the classic 4-file MNIST IDX layout from directory ``d``."""

    def pick(stem):
        for suf in ("", ".gz"):
            p = os.path.join(d, stem + suf)
            if os.path.exists(p):
                return p
        raise FileNotFoundError(stem)

    xtr = _read_idx(pick("train-images-idx3-ubyte")).astype(np.float32) / 255.0
    ytr = _read_idx(pick("train-labels-idx1-ubyte"))
    xte = _read_idx(pick("t10k-images-idx3-ubyte")).astype(np.float32) / 255.0
    yte = _read_idx(pick("t10k-labels-idx1-ubyte"))
    return (xtr, ytr), (xte, yte)


def dataset(train_n: int, test_n: int, seed: int, mnist_dir: str | None = None):
    """(train_x, train_y), (test_x, test_y) — images (N, 28, 28) f32."""
    if mnist_dir and os.path.isdir(mnist_dir):
        (xtr, ytr), (xte, yte) = load_mnist_idx(mnist_dir)
        return (xtr[:train_n], ytr[:train_n]), (xte[:test_n], yte[:test_n])
    xtr, ytr = generate(train_n, seed)
    xte, yte = generate(test_n, seed + 1)
    return (xtr, ytr), (xte, yte)


def pad32(x: np.ndarray) -> np.ndarray:
    """28×28 → 32×32 zero-pad (LeNet-5's canonical input size)."""
    return np.pad(x, ((0, 0), (2, 2), (2, 2)))
