"""L1 Pallas kernel: tiled im2col-matmul convolution.

The convolution is phrased the TPU way: the L2 model extracts im2col
patches (a relayout, done once per layer in plain jnp so XLA fuses it),
and the hot-spot — the (M, K) × (K, N) contraction — runs as a Pallas
kernel tiled for VMEM, with each grid step feeding one (TM, K)·(K, N)
block to the MXU.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see DESIGN.md
§Hardware-Adaptation).  Block shapes are still chosen as if for VMEM —
the structure, not the interpreter wallclock, is what carries to TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile for the patch matrix. LeNet-5 M values are B*784, B*100, B*1.
# VMEM budget: a (TM, K≤400) x-block + (K, Cout≤120) w-block + (TM, Cout)
# o-block at TM=512 is ≈ 1.1 MiB — comfortably inside a 16 MiB VMEM budget
# and MXU-aligned on the row dimension. §Perf iterations 4-5 (see
# EXPERIMENTS.md): TM 128 → 512 quartered the grid-step count (the
# dominant interpret-mode overhead) and cut the b8 artifact latency 1.76x;
# TM 1024 regressed batch-1 by 26 % (pad rows dominate a 784-row layer)
# and was reverted. On real TPU the 512-row shape keeps the MXU fed for
# >=4 consecutive systolic passes per DMA.
DEFAULT_TM = 512


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref):
    """One grid step: o = x @ w + b over a (TM, K)·(K, N) VMEM tile."""
    x = x_ref[...]
    w = w_ref[...]
    # MXU contraction; preferred_element_type pins f32 accumulation.
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] = acc + b_ref[...]


@functools.partial(jax.jit, static_argnames=("tm",))
def matmul_bias(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, tm: int = DEFAULT_TM):
    """Pallas tiled ``x @ w + b``.

    x: (M, K), w: (K, N), b: (N,) → (M, N).  M is padded up to a multiple
    of the row tile; the pad rows are dropped before returning.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    tm = min(tm, max(m, 1))
    mp = ((m + tm - 1) // tm) * tm
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    grid = (mp // tm,)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:m]


def im2col(x: jnp.ndarray, kh: int, kw: int) -> jnp.ndarray:
    """Patch extraction, identical ordering to ``ref.im2col`` (c, dy, dx)."""
    b, c, h, w = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = [
        x[:, :, dy : dy + oh, dx : dx + ow] for dy in range(kh) for dx in range(kw)
    ]
    stack = jnp.stack(cols, axis=0).transpose(1, 3, 4, 2, 0)
    return stack.reshape(b, oh, ow, c * kh * kw)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Valid stride-1 convolution via the Pallas matmul kernel.

    x: (B, C, H, W), w: (Cout, C, kh, kw), b: (Cout,) → (B, Cout, OH, OW).
    """
    bsz, cin, h, _ = x.shape
    cout, _, kh, kw = w.shape
    oh, ow = h - kh + 1, x.shape[3] - kw + 1
    patches = im2col(x, kh, kw).reshape(bsz * oh * ow, cin * kh * kw)
    wmat = w.reshape(cout, cin * kh * kw).T  # (K, Cout)
    out = matmul_bias(patches, wmat, b)  # (B*OH*OW, Cout)
    return out.reshape(bsz, oh, ow, cout).transpose(0, 3, 1, 2)
