"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything in this module is deliberately written with plain ``jax.numpy``
(no ``pallas``, no ``lax.conv``) so it can serve as an independent
correctness oracle: the Pallas kernels in ``conv2d.py`` / ``subconv.py``
and the lax-based training path in ``train.py`` are both checked against
these functions by ``python/tests/``.

Layout convention: NCHW activations, OIHW weights (matches the rust
``nn`` engine so golden files transfer without transposes).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def im2col(x: jnp.ndarray, kh: int, kw: int) -> jnp.ndarray:
    """Extract valid-convolution patches.

    ``x``: (B, C, H, W)  →  (B, OH, OW, C*kh*kw) with the patch axis ordered
    (c, dy, dx) — the same order ``weights.reshape(Cout, -1)`` produces from
    OIHW weights, and the order the rust engine uses.
    """
    b, c, h, w = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(x[:, :, dy : dy + oh, dx : dx + ow])
    # (kh*kw, B, C, OH, OW) -> (B, OH, OW, C, kh*kw) -> (B, OH, OW, C*kh*kw)
    stack = jnp.stack(cols, axis=0)
    stack = stack.transpose(1, 3, 4, 2, 0)
    return stack.reshape(b, oh, ow, c * kh * kw)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Valid, stride-1 2-D convolution (cross-correlation, as in CNNs).

    ``x``: (B, C, H, W), ``w``: (Cout, C, kh, kw), ``b``: (Cout,)
    →  (B, Cout, OH, OW)
    """
    cout, cin, kh, kw = w.shape
    patches = im2col(x, kh, kw)  # (B, OH, OW, K)
    wmat = w.reshape(cout, cin * kh * kw)  # (Cout, K)
    out = jnp.einsum("bhwk,ck->bchw", patches, wmat)
    return out + b[None, :, None, None]


def subconv2d(
    x: jnp.ndarray,
    pair_i1: np.ndarray,
    pair_i2: np.ndarray,
    pair_k: np.ndarray,
    unp_idx: np.ndarray,
    unp_w: np.ndarray,
    bias: jnp.ndarray,
    kh: int,
    kw: int,
) -> jnp.ndarray:
    """Reference for the paired (subtractor-form) convolution.

    Implements the paper's modified convolution unit: combined weight pairs
    compute ``k * (I1 - I2)`` (one subtraction replaces one multiply + one
    add, eq. (1) of the paper), uncombined weights use the ordinary
    multiply-accumulate.

    Per output channel ``c`` the preprocessor supplies padded arrays:
      pair_i1/pair_i2: (Cout, Pmax) int32 patch indices of I1/I2,
      pair_k:          (Cout, Pmax) f32 snapped magnitudes (0 ⇒ padding),
      unp_idx:         (Cout, Umax) int32 indices of uncombined weights,
      unp_w:           (Cout, Umax) f32 values (0 ⇒ padding).
    """
    patches = im2col(x, kh, kw)  # (B, OH, OW, K)
    x1 = patches[..., pair_i1]  # (B, OH, OW, Cout, Pmax)
    x2 = patches[..., pair_i2]
    xu = patches[..., unp_idx]  # (B, OH, OW, Cout, Umax)
    out = jnp.einsum("bhwcp,cp->bchw", x1 - x2, pair_k)
    out = out + jnp.einsum("bhwcu,cu->bchw", xu, unp_w)
    return out + bias[None, :, None, None]


def avgpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2×2 average pooling, stride 2.  (B, C, H, W) → (B, C, H/2, W/2)."""
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).mean(axis=(3, 5))


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Fully connected layer.  x: (B, In), w: (Out, In), b: (Out,)."""
    return x @ w.T + b


def lenet5(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Reference LeNet-5 forward pass (paper Fig. 2).

    Input (B, 1, 32, 32) → logits (B, 10).  tanh activations, average
    pooling — the classic formulation the paper's op counts correspond to
    (conv MACs: C1 117 600 + C3 240 000 + C5 48 000 = 405 600).
    """
    h = jnp.tanh(conv2d(x, params["c1_w"], params["c1_b"]))  # (B,6,28,28)
    h = avgpool2(h)  # (B,6,14,14)
    h = jnp.tanh(conv2d(h, params["c3_w"], params["c3_b"]))  # (B,16,10,10)
    h = avgpool2(h)  # (B,16,5,5)
    h = jnp.tanh(conv2d(h, params["c5_w"], params["c5_b"]))  # (B,120,1,1)
    h = h.reshape(h.shape[0], 120)
    h = jnp.tanh(dense(h, params["f6_w"], params["f6_b"]))  # (B,84)
    return dense(h, params["out_w"], params["out_b"])  # (B,10)
