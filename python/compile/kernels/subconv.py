"""L1 Pallas kernel: the paper's subtractor-form convolution unit.

This is the TPU re-think of the paper's ASIC datapath (DESIGN.md
§Hardware-Adaptation).  The preprocessor (Algorithm 1) has already paired
each positive weight `Ka` with a negative weight `Kb ≈ -Ka` inside every
filter and snapped both to a common magnitude `k`; the kernel then
computes, per output channel,

    out[c] = Σ_p  k[c,p] · (I1[c,p] − I2[c,p])   ← subtractor lanes
           + Σ_u  w[c,u] · Iu[c,u]               ← ordinary MAC lanes
           + bias[c]

The input *difference* is formed first (VPU subtraction over a whole VMEM
tile), then contracted — that is the structural analogue of the paper's
"one subtraction replaces one multiply + one add": the multiply count of
the pair contraction is half that of the dense contraction it replaces.

Numerically the result is bit-identical (up to f32 reassociation) to a
dense convolution with the *modified* weights — property-tested against
``ref.subconv2d`` and ``ref.conv2d`` in python/tests/test_subconv.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import conv2d as _conv

DEFAULT_TM = 128


def _subconv_kernel(x_ref, i1_ref, i2_ref, pk_ref, iu_ref, wu_ref, b_ref, o_ref):
    """One grid step over a (TM, K) patch tile.

    Gathers are static-index (i1/i2/iu are compile-time-constant inputs in
    VMEM); the subtract runs element-wise on the gathered tiles before the
    contraction, mirroring the hardware subtractor placed ahead of the
    multiplier array in the paper's Fig. 5.
    """
    x = x_ref[...]  # (TM, K)
    i1 = i1_ref[...]  # (Cout, Pmax) int32
    i2 = i2_ref[...]
    pk = pk_ref[...]  # (Cout, Pmax) f32, 0 padded
    iu = iu_ref[...]  # (Cout, Umax) int32
    wu = wu_ref[...]  # (Cout, Umax) f32, 0 padded

    x1 = x[:, i1]  # (TM, Cout, Pmax)
    x2 = x[:, i2]
    diff = x1 - x2  # ← the subtractor lane
    pair_out = jnp.einsum("mcp,cp->mc", diff, pk)

    xu = x[:, iu]  # (TM, Cout, Umax)
    mac_out = jnp.einsum("mcu,cu->mc", xu, wu)

    o_ref[...] = pair_out + mac_out + b_ref[...]


@functools.partial(jax.jit, static_argnames=("tm",))
def paired_matmul(
    x: jnp.ndarray,
    pair_i1: jnp.ndarray,
    pair_i2: jnp.ndarray,
    pair_k: jnp.ndarray,
    unp_idx: jnp.ndarray,
    unp_w: jnp.ndarray,
    bias: jnp.ndarray,
    tm: int = DEFAULT_TM,
):
    """Paired contraction over patch rows.

    x: (M, K) im2col patches; pair/unp arrays as in ``ref.subconv2d``
    (padded per-channel); → (M, Cout).
    """
    m, k = x.shape
    cout = pair_i1.shape[0]
    tm = min(tm, max(m, 1))
    mp = ((m + tm - 1) // tm) * tm
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    pmax, umax = pair_i1.shape[1], unp_idx.shape[1]
    out = pl.pallas_call(
        _subconv_kernel,
        grid=(mp // tm,),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((cout, pmax), lambda i: (0, 0)),
            pl.BlockSpec((cout, pmax), lambda i: (0, 0)),
            pl.BlockSpec((cout, pmax), lambda i: (0, 0)),
            pl.BlockSpec((cout, umax), lambda i: (0, 0)),
            pl.BlockSpec((cout, umax), lambda i: (0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, cout), jnp.float32),
        interpret=True,
    )(x, pair_i1, pair_i2, pair_k, unp_idx, unp_w, bias)
    return out[:m]


def subconv2d(
    x: jnp.ndarray,
    pair_i1,
    pair_i2,
    pair_k,
    unp_idx,
    unp_w,
    bias,
    kh: int,
    kw: int,
) -> jnp.ndarray:
    """Paired (subtractor-form) convolution via the Pallas kernel.

    Same contract as ``ref.subconv2d``: x (B, C, H, W) → (B, Cout, OH, OW).
    """
    bsz, cin, h, w = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    patches = _conv.im2col(x, kh, kw).reshape(bsz * oh * ow, cin * kh * kw)
    out = paired_matmul(
        patches,
        jnp.asarray(pair_i1, jnp.int32),
        jnp.asarray(pair_i2, jnp.int32),
        jnp.asarray(pair_k, jnp.float32),
        jnp.asarray(unp_idx, jnp.int32),
        jnp.asarray(unp_w, jnp.float32),
        jnp.asarray(bias, jnp.float32),
    )
    cout = out.shape[1]
    return out.reshape(bsz, oh, ow, cout).transpose(0, 3, 1, 2)
