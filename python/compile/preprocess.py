"""Reference (numpy) implementation of the paper's weight preprocessor.

Section III-A / Algorithm 1: per conv filter, sort the weights, split into
positive and negative lists, then walk both lists with two pointers from
the smallest magnitude upward.  A positive weight ``Ka`` and a negative
weight ``Kb`` are *combined* when their magnitudes agree within the
``rounding`` size; both are snapped to the mean magnitude ``k`` so that
``Kb = -Ka`` holds exactly and inference can use ``k · (I1 − I2)``.

This module is the cross-validation oracle for the production
implementation in ``rust/src/accel/preprocess.rs`` — both sides must
produce identical pairings and identical modified weights on the shared
trained model (checked via artifacts/golden files).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class FilterPairing:
    """Pairing result for one conv filter (one output channel)."""

    pair_i1: list = field(default_factory=list)  # flat index of the + weight
    pair_i2: list = field(default_factory=list)  # flat index of the − weight
    pair_k: list = field(default_factory=list)  # snapped magnitude
    unp_idx: list = field(default_factory=list)  # uncombined flat indices
    unp_w: list = field(default_factory=list)  # uncombined values


def pair_filter(w: np.ndarray, rounding: float) -> FilterPairing:
    """Algorithm 1 on one flattened filter ``w`` (K,).

    Combination rule (lines 4–17):
      PP.val ≥ |PN.val| + rounding → negative too small, mark PN uncombined
      PP.val ≤ |PN.val| − rounding → positive too small, mark PP uncombined
      otherwise                    → combine, advance both
    Both lists are walked in ascending magnitude order.
    """
    w = np.asarray(w, dtype=np.float32).ravel()
    res = FilterPairing()

    pos = [(v, i) for i, v in enumerate(w) if v > 0]
    neg = [(v, i) for i, v in enumerate(w) if v < 0]
    zer = [(v, i) for i, v in enumerate(w) if v == 0]
    pos.sort(key=lambda t: t[0])  # ascending value = ascending magnitude
    neg.sort(key=lambda t: -t[0])  # ascending magnitude for negatives

    pp, pn = 0, 0
    while pp < len(pos) and pn < len(neg):
        pv, pi = pos[pp]
        nv, ni = neg[pn]
        if pv >= -nv + rounding:  # negative weight too small
            res.unp_idx.append(ni)
            res.unp_w.append(nv)
            pn += 1
        elif pv <= -nv - rounding:  # positive weight too small
            res.unp_idx.append(pi)
            res.unp_w.append(pv)
            pp += 1
        else:  # combine
            k = np.float32((pv + (-nv)) / 2.0)
            res.pair_i1.append(pi)
            res.pair_i2.append(ni)
            res.pair_k.append(float(k))
            pp += 1
            pn += 1
    # leftovers stay uncombined
    for v, i in pos[pp:]:
        res.unp_idx.append(i)
        res.unp_w.append(v)
    for v, i in neg[pn:]:
        res.unp_idx.append(i)
        res.unp_w.append(v)
    for v, i in zer:
        res.unp_idx.append(i)
        res.unp_w.append(v)
    return res


def modified_weights(w: np.ndarray, rounding: float) -> np.ndarray:
    """Snapped weight tensor: dense conv with this tensor is numerically
    identical to the paired subtractor-form computation."""
    cout = w.shape[0]
    flat = w.reshape(cout, -1).astype(np.float32).copy()
    for c in range(cout):
        p = pair_filter(flat[c], rounding)
        for i1, i2, k in zip(p.pair_i1, p.pair_i2, p.pair_k):
            flat[c, i1] = k
            flat[c, i2] = -k
    return flat.reshape(w.shape)


def padded_pairing(w: np.ndarray, rounding: float, pmax=None, umax=None):
    """Per-layer padded arrays for the subconv kernels.

    Returns (pair_i1, pair_i2, pair_k, unp_idx, unp_w) with shapes
    (Cout, Pmax) / (Cout, Umax); k = 0 and w = 0 mark padding (index 0 is
    used as a harmless dummy gather target).
    """
    cout = w.shape[0]
    k_len = int(np.prod(w.shape[1:]))
    pairs = [pair_filter(w.reshape(cout, -1)[c], rounding) for c in range(cout)]
    pmax = pmax if pmax is not None else max(1, max(len(p.pair_k) for p in pairs))
    umax = umax if umax is not None else max(1, max(len(p.unp_w) for p in pairs))
    i1 = np.zeros((cout, pmax), np.int32)
    i2 = np.zeros((cout, pmax), np.int32)
    pk = np.zeros((cout, pmax), np.float32)
    iu = np.zeros((cout, umax), np.int32)
    wu = np.zeros((cout, umax), np.float32)
    for c, p in enumerate(pairs):
        npair, nunp = len(p.pair_k), len(p.unp_w)
        assert npair <= pmax and nunp <= umax, "padding sizes too small"
        assert 2 * npair + nunp == k_len, "pairing lost weights"
        i1[c, :npair] = p.pair_i1
        i2[c, :npair] = p.pair_i2
        pk[c, :npair] = p.pair_k
        iu[c, :nunp] = p.unp_idx
        wu[c, :nunp] = p.unp_w
    return i1, i2, pk, iu, wu


def count_ops(w: np.ndarray, out_positions: int, rounding: float):
    """Per-inference op counts for one conv layer (paper Table 1 semantics).

    Baseline: every weight costs 1 multiply + 1 accumulate-add per output
    position.  Every combined pair replaces (2 mul + 2 add) with
    (1 sub + 1 mul + 1 add).  Bias adds are not counted (the paper's
    rounding-0 row is exactly the MAC count, 405 600 for LeNet-5).
    """
    cout = w.shape[0]
    flat = w.reshape(cout, -1)
    k_len = flat.shape[1]
    pairs = sum(len(pair_filter(flat[c], rounding).pair_k) for c in range(cout))
    base = cout * k_len * out_positions
    subs = pairs * out_positions
    muls = base - subs
    adds = base - subs
    return {"adds": adds, "subs": subs, "muls": muls, "total": adds + subs + muls}
