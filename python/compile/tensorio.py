"""Tiny named-tensor container format shared with the rust side.

One format for everything crossing the python→rust boundary (trained
weights, datasets, golden inputs/outputs, pairing tables):

    magic   b"STDI"
    u32 LE  version (1)
    u32 LE  tensor count
    per tensor:
        u16 LE  name length, then UTF-8 name
        u8      dtype  (0 = f32, 1 = i32, 2 = u8)
        u8      ndim
        u32 LE  dims[ndim]
        raw     data, little-endian, C order

Mirrored by ``rust/src/data/tensorio.rs``; both sides have round-trip
tests and the integration suite reads python-written files from rust.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"STDI"
VERSION = 1
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def save(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _CODES[arr.dtype], arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def load(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        out: dict[str, np.ndarray] = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = _DTYPES[code]
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * np.dtype(dt).itemsize), dtype=dt)
            out[name] = data.reshape(dims).copy()
        return out
