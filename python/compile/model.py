"""L2: LeNet-5 in JAX, calling the L1 Pallas kernels.

Two forward paths share one parameter pytree:

  * ``lenet5``        — inference path used for the AOT artifact; conv
    layers run through the Pallas im2col-matmul kernel (kernels.conv2d).
    Weights are *function arguments*, so a single HLO artifact serves
    every rounding variant (the rust coordinator feeds modified weights).
  * ``lenet5_train``  — training path on ``lax.conv_general_dilated``
    (fastest on CPU for the build-time trainer); numerically equivalent,
    asserted in python/tests/test_model.py.

Parameter names/order are the wire contract with rust — see PARAM_NAMES.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv2d as pconv
from .kernels import ref

# Wire order of LeNet-5 parameters (weights.bin keys and the argument
# order of the AOT-lowered HLO after the image input).
PARAM_NAMES = [
    "c1_w", "c1_b",
    "c3_w", "c3_b",
    "c5_w", "c5_b",
    "f6_w", "f6_b",
    "out_w", "out_b",
]

PARAM_SHAPES = {
    "c1_w": (6, 1, 5, 5), "c1_b": (6,),
    "c3_w": (16, 6, 5, 5), "c3_b": (16,),
    "c5_w": (120, 16, 5, 5), "c5_b": (120,),
    "f6_w": (84, 120), "f6_b": (84,),
    "out_w": (10, 84), "out_b": (10,),
}

CONV_LAYERS = {  # name -> (weight key, output positions OH*OW)
    "c1": ("c1_w", 28 * 28),
    "c3": ("c3_w", 10 * 10),
    "c5": ("c5_w", 1 * 1),
}


def init_params(seed: int) -> dict:
    """Glorot-uniform init, f32."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in PARAM_SHAPES.items():
        if name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = int(np.prod(shape[1:]))
            fan_out = shape[0]
            lim = np.sqrt(6.0 / (fan_in + fan_out))
            params[name] = jnp.asarray(
                rng.uniform(-lim, lim, shape), dtype=jnp.float32
            )
    return params


def _head(params: dict, h: jnp.ndarray) -> jnp.ndarray:
    h = h.reshape(h.shape[0], 120)
    h = jnp.tanh(ref.dense(h, params["f6_w"], params["f6_b"]))
    return ref.dense(h, params["out_w"], params["out_b"])


def lenet5(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Inference forward on the Pallas conv kernel.  x (B,1,32,32) → (B,10)."""
    h = jnp.tanh(pconv.conv2d(x, params["c1_w"], params["c1_b"]))
    h = ref.avgpool2(h)
    h = jnp.tanh(pconv.conv2d(h, params["c3_w"], params["c3_b"]))
    h = ref.avgpool2(h)
    h = jnp.tanh(pconv.conv2d(h, params["c5_w"], params["c5_b"]))
    return _head(params, h)


def _lax_conv(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def lenet5_train(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Training forward on lax.conv (build-time only, never exported)."""
    h = jnp.tanh(_lax_conv(x, params["c1_w"], params["c1_b"]))
    h = ref.avgpool2(h)
    h = jnp.tanh(_lax_conv(h, params["c3_w"], params["c3_b"]))
    h = ref.avgpool2(h)
    h = jnp.tanh(_lax_conv(h, params["c5_w"], params["c5_b"]))
    return _head(params, h)


def lenet5_flat(x: jnp.ndarray, *flat_params) -> tuple[jnp.ndarray]:
    """Flat-argument wrapper for AOT lowering: (x, w0, w1, ...) → (logits,).

    Returns a 1-tuple because the HLO is lowered with return_tuple=True and
    the rust side unwraps with to_tuple1() (see /opt/xla-example/README.md).
    """
    params = dict(zip(PARAM_NAMES, flat_params))
    return (lenet5(params, x),)


def lenet5_xla_flat(x: jnp.ndarray, *flat_params) -> tuple[jnp.ndarray]:
    """Same contract on lax.conv — the XLA-native baseline artifact used in
    the §Perf comparison (pallas-interpret vs native conv on CPU PJRT)."""
    params = dict(zip(PARAM_NAMES, flat_params))
    return (lenet5_train(params, x),)


# Fixed padded pairing-table sizes per conv layer for the fully-paired
# artifact: (Cout, Pmax = K//2, Umax = K). Shared contract with rust.
PAIRED_TABLE_SIZES = {
    "c1": (6, 12, 25),
    "c3": (16, 75, 150),
    "c5": (120, 200, 400),
}


def lenet5_paired_flat(x: jnp.ndarray, *args) -> tuple[jnp.ndarray]:
    """LeNet-5 with ALL conv layers in the paper's subtractor form.

    The paired datapath itself is the serving artifact: for each conv
    layer the caller supplies runtime pairing tables
    ``(i1, i2, k, iu, wu, bias)`` produced by Algorithm 1 (rust or numpy),
    followed by the dense head weights. Argument order:

        x,
        c1: i1, i2, pk, iu, wu, bias,
        c3: ..., c5: ...,
        f6_w, f6_b, out_w, out_b
    """
    from .kernels import subconv as psub

    it = iter(args)
    h = x
    for name in ("c1", "c3", "c5"):
        i1, i2, pk, iu, wu, bias = (next(it) for _ in range(6))
        h = jnp.tanh(psub.subconv2d(h, i1, i2, pk, iu, wu, bias, 5, 5))
        if name != "c5":
            h = ref.avgpool2(h)
    f6_w, f6_b, out_w, out_b = (next(it) for _ in range(4))
    h = h.reshape(h.shape[0], 120)
    h = jnp.tanh(ref.dense(h, f6_w, f6_b))
    return (ref.dense(h, out_w, out_b),)
