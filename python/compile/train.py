"""Build-time trainer: LeNet-5 on (synthetic) MNIST → artifacts/weights.bin.

The paper starts from a pre-trained network (PyTorch in the original); the
training framework is immaterial to the method, so we train with plain
jax.grad + a hand-rolled Adam (optax is not in the image).  Runs once from
``make artifacts``; never on the request path.

Also emits:
  artifacts/dataset.bin — held-out test set (images u8, labels u8) the rust
    side uses for accuracy sweeps and serving demos,
  artifacts/golden.bin  — 32 test inputs + reference logits (f32) used by
    rust integration tests to cross-check the whole stack.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model, synth_mnist, tensorio


def cross_entropy(params, x, y):
    logits = model.lenet5_train(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


@jax.jit
def adam_step(params, m, v, t, x, y, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    loss, grads = jax.value_and_grad(cross_entropy)(params, x, y)
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, m, v, loss


@jax.jit
def predict(params, x):
    return jnp.argmax(model.lenet5_train(params, x), axis=-1)


def accuracy(params, x, y, batch=256) -> float:
    hits = 0
    for i in range(0, x.shape[0], batch):
        hits += int(jnp.sum(predict(params, x[i : i + batch]) == y[i : i + batch]))
    return hits / x.shape[0]


def train(
    train_n=6000,
    test_n=1500,
    epochs=4,
    batch=128,
    seed=7,
    mnist_dir=None,
    log=print,
):
    (xtr, ytr), (xte, yte) = synth_mnist.dataset(train_n, test_n, seed, mnist_dir)
    xtr32 = synth_mnist.pad32(xtr)[:, None, :, :].astype(np.float32)
    xte32 = synth_mnist.pad32(xte)[:, None, :, :].astype(np.float32)
    ytr_i = ytr.astype(np.int32)
    yte_i = yte.astype(np.int32)

    params = model.init_params(seed)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    t = 0
    loss_curve = []
    for ep in range(epochs):
        order = rng.permutation(train_n)
        t0 = time.time()
        ep_loss = 0.0
        nb = 0
        for i in range(0, train_n - batch + 1, batch):
            idx = order[i : i + batch]
            t += 1
            params, m, v, loss = adam_step(
                params, m, v, jnp.float32(t), xtr32[idx], ytr_i[idx]
            )
            ep_loss += float(loss)
            nb += 1
        acc = accuracy(params, xte32, yte_i)
        loss_curve.append(ep_loss / nb)
        log(
            f"epoch {ep + 1}/{epochs}  loss={ep_loss / nb:.4f}  "
            f"test_acc={acc:.4f}  ({time.time() - t0:.1f}s)"
        )
    return params, (xte, yte), xte32, yte_i, loss_curve


def export(outdir: str, params, test_raw, xte32, yte, loss_curve):
    os.makedirs(outdir, exist_ok=True)
    # 1. trained weights
    tensorio.save(
        os.path.join(outdir, "weights.bin"),
        {k: np.asarray(params[k]) for k in model.PARAM_NAMES},
    )
    # 2. held-out test set (u8 images to keep the file small)
    xte, yte_u8 = test_raw
    tensorio.save(
        os.path.join(outdir, "dataset.bin"),
        {
            "images": (xte * 255.0 + 0.5).astype(np.uint8),
            "labels": yte_u8.astype(np.uint8),
        },
    )
    # 3. golden inputs/outputs for rust cross-validation (pure-jnp ref path)
    from .kernels import ref as _ref

    gx = xte32[:32]
    glog = np.asarray(model.lenet5_train(params, gx))
    ref_log = np.asarray(_ref.lenet5(params, gx))
    np.testing.assert_allclose(glog, ref_log, rtol=2e-4, atol=2e-4)
    tensorio.save(
        os.path.join(outdir, "golden.bin"),
        {
            "inputs": np.asarray(gx, np.float32),
            "logits": ref_log.astype(np.float32),
            "loss_curve": np.asarray(loss_curve, np.float32),
        },
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-n", type=int, default=6000)
    ap.add_argument("--test-n", type=int, default=1500)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--mnist", default=os.environ.get("MNIST_DIR"))
    args = ap.parse_args()
    params, test_raw, xte32, yte, curve = train(
        args.train_n, args.test_n, args.epochs, seed=args.seed, mnist_dir=args.mnist
    )
    export(args.out, params, test_raw, xte32, yte, curve)
    print(f"wrote weights/dataset/golden to {args.out}")


if __name__ == "__main__":
    main()
