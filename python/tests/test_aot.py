"""AOT lowering: HLO text is parseable-looking, has the right parameter
count, and round-trips through jax's own HLO parser when available."""

import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def entry_param_count(text: str) -> int:
    """Count parameters of the ENTRY computation only (nested computations
    — fusions, reduce bodies, pallas while-loops — have their own)."""
    entry = text[text.index("ENTRY ") :]
    body = entry[: entry.index("\n}")]
    return body.count(" parameter(")


def test_lenet5_hlo_has_all_params():
    text = aot.lower_lenet5(1, xla_native=True)
    assert "HloModule" in text
    # 1 image + 10 weight tensors
    assert entry_param_count(text) == 1 + len(model.PARAM_NAMES)
    assert "f32[1,1,32,32]" in text
    assert "f32[10,84]" in text


def test_lenet5_pallas_hlo_lowering():
    text = aot.lower_lenet5(1, xla_native=False)
    assert "HloModule" in text
    assert entry_param_count(text) == 1 + len(model.PARAM_NAMES)
    # interpret-mode pallas lowers to plain HLO: no Mosaic custom-calls
    assert "mosaic" not in text.lower()


def test_subconv_hlo_lowering():
    text = aot.lower_subconv_c3(1)
    assert "HloModule" in text
    assert entry_param_count(text) == 7
    assert "s32[16,75]" in text  # pairing index tables are runtime args


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "lenet5_b1.hlo.txt")),
    reason="run make artifacts",
)
def test_artifacts_on_disk_complete():
    for b in aot.BATCH_SIZES:
        for tag in ("lenet5", "lenet5_xla"):
            p = os.path.join(ART, f"{tag}_b{b}.hlo.txt")
            assert os.path.exists(p), p
            assert "HloModule" in open(p).read(200)
    assert os.path.exists(os.path.join(ART, "subconv_c3_b1.hlo.txt"))
