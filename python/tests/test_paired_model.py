"""Fully-paired LeNet-5 graph (the serving artifact where the subtractor
datapath IS the model): equivalence vs dense-modified, and lowering."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, preprocess as pp


def build_args(params, x, rounding):
    args = [x]
    mod = dict(params)
    for name in ("c1", "c3", "c5"):
        cout, pmax, umax = model.PAIRED_TABLE_SIZES[name]
        wt = np.asarray(params[f"{name}_w"])
        i1, i2, pk, iu, wu = pp.padded_pairing(wt, rounding, pmax, umax)
        args += [
            jnp.asarray(i1), jnp.asarray(i2), jnp.asarray(pk),
            jnp.asarray(iu), jnp.asarray(wu), params[f"{name}_b"],
        ]
        mod[f"{name}_w"] = jnp.asarray(pp.modified_weights(wt, rounding))
    args += [params["f6_w"], params["f6_b"], params["out_w"], params["out_b"]]
    return args, mod


@pytest.mark.parametrize("rounding", [0.0, 0.05, 0.3])
def test_paired_full_model_matches_dense_modified(rounding):
    params = model.init_params(5)
    x = jnp.asarray(
        np.random.default_rng(1).normal(size=(2, 1, 32, 32)).astype(np.float32)
    )
    args, mod = build_args(params, x, rounding)
    (got,) = model.lenet5_paired_flat(*args)
    want = model.lenet5_train(mod, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_paired_table_sizes_cover_worst_case():
    # Pmax = K//2 is the theoretical max pair count per filter
    for name, (cout, pmax, umax) in model.PAIRED_TABLE_SIZES.items():
        shape = model.PARAM_SHAPES[f"{name}_w"]
        k = int(np.prod(shape[1:]))
        assert shape[0] == cout
        assert pmax == k // 2
        assert umax == k


def test_paired_lowering_has_all_args():
    text = aot.lower_paired_lenet5(1)
    assert "HloModule" in text
    entry = text[text.index("ENTRY ") :]
    body = entry[: entry.index("\n}")]
    # 1 image + 3 layers × 6 tables + 4 head tensors
    assert body.count(" parameter(") == 1 + 18 + 4
