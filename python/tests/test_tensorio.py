"""tensorio round-trip + format-edge tests (wire contract with rust)."""

import struct

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tensorio


def test_roundtrip_mixed(tmp_path):
    p = str(tmp_path / "t.bin")
    data = {
        "f": np.arange(12, dtype=np.float32).reshape(3, 4),
        "i": np.array([[-1, 2], [3, -4]], np.int32),
        "u": np.arange(255, dtype=np.uint8),
        "scalarish": np.array([3.5], np.float32),
    }
    tensorio.save(p, data)
    out = tensorio.load(p)
    assert set(out) == set(data)
    for k in data:
        np.testing.assert_array_equal(out[k], data[k])
        assert out[k].dtype == data[k].dtype


@settings(max_examples=20, deadline=None)
@given(
    ndim=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_hypothesis(tmp_path_factory, ndim, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(int(x) for x in rng.integers(1, 6, ndim))
    arr = rng.normal(size=shape).astype(np.float32)
    p = str(tmp_path_factory.mktemp("tio") / "t.bin")
    tensorio.save(p, {"x": arr})
    np.testing.assert_array_equal(tensorio.load(p)["x"], arr)


def test_bad_magic_rejected(tmp_path):
    p = str(tmp_path / "bad.bin")
    with open(p, "wb") as f:
        f.write(b"NOPE" + struct.pack("<II", 1, 0))
    with pytest.raises(ValueError, match="bad magic"):
        tensorio.load(p)


def test_bad_version_rejected(tmp_path):
    p = str(tmp_path / "bad.bin")
    with open(p, "wb") as f:
        f.write(tensorio.MAGIC + struct.pack("<II", 99, 0))
    with pytest.raises(ValueError, match="version"):
        tensorio.load(p)


def test_unsupported_dtype_rejected(tmp_path):
    p = str(tmp_path / "t.bin")
    with pytest.raises(TypeError):
        tensorio.save(p, {"d": np.zeros(3, np.float64)})


def test_empty_dict_roundtrip(tmp_path):
    p = str(tmp_path / "e.bin")
    tensorio.save(p, {})
    assert tensorio.load(p) == {}
