"""Build-time trainer: convergence on a tiny run + export contract."""

import numpy as np

from compile import model, tensorio, train


def test_adam_step_reduces_loss():
    import jax
    import jax.numpy as jnp

    params = model.init_params(0)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 1, 32, 32)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, 16).astype(np.int32))
    l0 = float(train.cross_entropy(params, x, y))
    t = 0
    for _ in range(10):
        t += 1
        params, m, v, loss = train.adam_step(params, m, v, jnp.float32(t), x, y)
    l1 = float(train.cross_entropy(params, x, y))
    assert l1 < l0, f"loss did not drop: {l0} -> {l1}"


def test_tiny_training_run_converges_and_exports(tmp_path):
    params, test_raw, xte32, yte, curve = train.train(
        train_n=512, test_n=128, epochs=2, batch=64, seed=11, log=lambda *_: None
    )
    assert len(curve) == 2 and curve[1] < curve[0]
    acc = train.accuracy(params, xte32, yte)
    assert acc > 0.5, f"tiny run should beat chance by far, got {acc}"

    out = str(tmp_path)
    train.export(out, params, test_raw, xte32, yte, curve)
    w = tensorio.load(f"{out}/weights.bin")
    assert set(w) == set(model.PARAM_NAMES)
    g = tensorio.load(f"{out}/golden.bin")
    assert g["inputs"].shape == (32, 1, 32, 32)
    assert g["logits"].shape == (32, 10)
    d = tensorio.load(f"{out}/dataset.bin")
    assert d["images"].shape[0] == d["labels"].shape[0] == 128
