"""Pallas conv2d kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (batch, channels, spatial, kernel size) and the
row-tile parameter; every case must match ``ref.conv2d`` to f32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as pconv
from compile.kernels import ref

RTOL, ATOL = 1e-4, 1e-4


def rand(shape, seed):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    )


@pytest.mark.parametrize(
    "b,cin,h,w,cout,k",
    [
        (1, 1, 32, 32, 6, 5),   # LeNet C1
        (2, 6, 14, 14, 16, 5),  # LeNet C3
        (1, 16, 5, 5, 120, 5),  # LeNet C5
        (3, 2, 9, 7, 4, 3),     # non-square input
        (1, 1, 1, 1, 1, 1),     # degenerate 1×1
    ],
)
def test_conv2d_matches_ref(b, cin, h, w, cout, k):
    x = rand((b, cin, h, w), 1)
    wt = rand((cout, cin, k, k), 2)
    bias = rand((cout,), 3)
    got = pconv.conv2d(x, wt, bias)
    want = ref.conv2d(x, wt, bias)
    assert got.shape == (b, cout, h - k + 1, w - k + 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), RTOL, ATOL)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    cin=st.integers(1, 4),
    extra=st.integers(0, 6),
    cout=st.integers(1, 8),
    k=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_hypothesis(b, cin, extra, cout, k, seed):
    h = w = k + extra
    x = rand((b, cin, h, w), seed)
    wt = rand((cout, cin, k, k), seed + 1)
    bias = rand((cout,), seed + 2)
    got = pconv.conv2d(x, wt, bias)
    want = ref.conv2d(x, wt, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), RTOL, ATOL)


@settings(max_examples=10, deadline=None)
@given(tm=st.sampled_from([1, 2, 8, 32, 128, 512]), m=st.integers(1, 200))
def test_matmul_tile_sizes(tm, m):
    """Row-tiling must be invisible: any tile size, any (unaligned) M."""
    x = rand((m, 13), m)
    w = rand((13, 7), m + 1)
    b = rand((7,), m + 2)
    got = pconv.matmul_bias(x, w, b, tm=tm)
    want = np.asarray(x) @ np.asarray(w) + np.asarray(b)
    np.testing.assert_allclose(np.asarray(got), want, RTOL, ATOL)


def test_im2col_ordering_matches_ref():
    """Patch axis ordering (c, dy, dx) is the wire contract with rust."""
    x = rand((1, 3, 6, 6), 9)
    a = pconv.im2col(x, 3, 3)
    b = ref.im2col(x, 3, 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_conv2d_dtype_is_f32():
    x = rand((1, 1, 8, 8), 0)
    wt = rand((2, 1, 3, 3), 1)
    bias = rand((2,), 2)
    assert pconv.conv2d(x, wt, bias).dtype == jnp.float32
