"""Synthetic-MNIST generator: determinism, value ranges, class structure."""

import numpy as np

from compile import synth_mnist


def test_deterministic():
    a, la = synth_mnist.generate(40, seed=3)
    b, lb = synth_mnist.generate(40, seed=3)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


def test_seed_changes_data():
    a, _ = synth_mnist.generate(10, seed=1)
    b, _ = synth_mnist.generate(10, seed=2)
    assert not np.array_equal(a, b)


def test_shapes_and_range():
    x, y = synth_mnist.generate(30, seed=0)
    assert x.shape == (30, 28, 28) and x.dtype == np.float32
    assert y.shape == (30,) and y.dtype == np.uint8
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert x.max() > 0.3  # ink actually present


def test_balanced_classes():
    _, y = synth_mnist.generate(100, seed=5)
    counts = np.bincount(y, minlength=10)
    assert (counts == 10).all()


def test_classes_are_distinguishable():
    """Mean images of different digits must differ substantially — the
    substitution argument (DESIGN.md §3) needs learnable structure."""
    x, y = synth_mnist.generate(200, seed=8)
    means = np.stack([x[y == d].mean(0) for d in range(10)])
    for a in range(10):
        for b in range(a + 1, 10):
            assert np.abs(means[a] - means[b]).mean() > 0.01


def test_pad32():
    x, _ = synth_mnist.generate(4, seed=0)
    p = synth_mnist.pad32(x)
    assert p.shape == (4, 32, 32)
    assert (p[:, :2, :] == 0).all() and (p[:, :, :2] == 0).all()
    np.testing.assert_array_equal(p[:, 2:30, 2:30], x)
