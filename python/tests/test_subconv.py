"""Subtractor-form conv kernel: the paper's eq. (1) must be numerically
invisible — paired computation ≡ dense conv with the modified weights."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import preprocess as pp
from compile.kernels import ref, subconv

RTOL, ATOL = 1e-4, 1e-4


def rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


def _check_equivalence(b, cin, h, w, cout, k, rounding, seed):
    x = jnp.asarray(rand((b, cin, h, w), seed))
    wt = rand((cout, cin, k, k), seed + 1)
    bias = jnp.asarray(rand((cout,), seed + 2))

    wmod = pp.modified_weights(wt, rounding)
    i1, i2, pk, iu, wu = pp.padded_pairing(wt, rounding)

    dense = ref.conv2d(x, jnp.asarray(wmod), bias)
    r_sub = ref.subconv2d(x, i1, i2, pk, iu, wu, bias, k, k)
    p_sub = subconv.subconv2d(x, i1, i2, pk, iu, wu, bias, k, k)

    np.testing.assert_allclose(np.asarray(r_sub), np.asarray(dense), RTOL, ATOL)
    np.testing.assert_allclose(np.asarray(p_sub), np.asarray(dense), RTOL, ATOL)


@pytest.mark.parametrize("rounding", [0.0, 0.0001, 0.01, 0.05, 0.1, 0.3, 10.0])
def test_equivalence_rounding_sweep(rounding):
    _check_equivalence(2, 3, 10, 10, 5, 4, rounding, 42)


@pytest.mark.parametrize(
    "b,cin,h,w,cout,k",
    [
        (1, 1, 32, 32, 6, 5),   # LeNet C1
        (1, 6, 14, 14, 16, 5),  # LeNet C3
        (1, 16, 5, 5, 120, 5),  # LeNet C5
    ],
)
def test_equivalence_lenet_geometry(b, cin, h, w, cout, k):
    _check_equivalence(b, cin, h, w, cout, k, 0.05, 7)


@settings(max_examples=20, deadline=None)
@given(
    cin=st.integers(1, 3),
    extra=st.integers(0, 4),
    cout=st.integers(1, 6),
    k=st.integers(1, 4),
    rounding=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_equivalence_hypothesis(cin, extra, cout, k, rounding, seed):
    h = w = k + extra
    _check_equivalence(1, cin, h, w, cout, k, rounding, seed)


def test_rounding_zero_is_identity():
    """rounding = 0 must leave the network bit-identical (Table 1 row 0:
    zero subtractions, original weights untouched)."""
    wt = rand((4, 3, 5, 5), 3)
    wmod = pp.modified_weights(wt, 0.0)
    np.testing.assert_array_equal(wmod, wt)
    i1, i2, pk, iu, wu = pp.padded_pairing(wt, 0.0)
    assert np.all(pk == 0.0)


def test_huge_rounding_pairs_everything_possible():
    """rounding → ∞ pairs min(#pos, #neg) weights per filter."""
    wt = rand((3, 2, 4, 4), 11)
    cout = wt.shape[0]
    flat = wt.reshape(cout, -1)
    for c in range(cout):
        p = pp.pair_filter(flat[c], 1e9)
        npos = int((flat[c] > 0).sum())
        nneg = int((flat[c] < 0).sum())
        assert len(p.pair_k) == min(npos, nneg)
