"""L2 model checks: shapes, path equivalence (pallas vs lax vs ref), and
the trained-artifact contract (weights/golden files round-trip)."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, tensorio
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def rand_x(b, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(b, 1, 32, 32)).astype(np.float32)
    )


def test_param_shapes():
    p = model.init_params(0)
    assert set(p) == set(model.PARAM_NAMES)
    for k, v in p.items():
        assert v.shape == model.PARAM_SHAPES[k]
        assert v.dtype == jnp.float32


def test_forward_shapes():
    p = model.init_params(1)
    for b in (1, 2, 8):
        assert model.lenet5(p, rand_x(b)).shape == (b, 10)
        assert model.lenet5_train(p, rand_x(b)).shape == (b, 10)


def test_three_paths_agree():
    """pallas path ≡ lax.conv path ≡ pure-jnp ref path."""
    p = model.init_params(2)
    x = rand_x(4, 3)
    a = np.asarray(model.lenet5(p, x))
    b = np.asarray(model.lenet5_train(p, x))
    c = np.asarray(ref.lenet5(p, x))
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(a, c, rtol=2e-4, atol=2e-4)


def test_flat_wrapper_matches_dict():
    p = model.init_params(4)
    x = rand_x(2, 5)
    flat = [p[n] for n in model.PARAM_NAMES]
    (out,) = model.lenet5_flat(x, *flat)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(model.lenet5(p, x)), rtol=1e-5, atol=1e-5
    )


def test_conv_mac_count_is_405600():
    """The Table-1 baseline is fixed by geometry; pin it here."""
    total = 0
    for name, (wkey, pos) in model.CONV_LAYERS.items():
        shape = model.PARAM_SHAPES[wkey]
        total += int(np.prod(shape)) * pos
    assert total == 405600


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "weights.bin")), reason="run make artifacts"
)
def test_trained_weights_roundtrip_and_goldens():
    w = tensorio.load(os.path.join(ART, "weights.bin"))
    assert set(w) == set(model.PARAM_NAMES)
    params = {k: jnp.asarray(v) for k, v in w.items()}
    g = tensorio.load(os.path.join(ART, "golden.bin"))
    logits = np.asarray(ref.lenet5(params, jnp.asarray(g["inputs"])))
    np.testing.assert_allclose(logits, g["logits"], rtol=2e-4, atol=2e-4)
    # the golden logits must classify sensibly (trained net, not noise)
    assert (logits.argmax(-1) == g["logits"].argmax(-1)).all()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "dataset.bin")), reason="run make artifacts"
)
def test_dataset_artifact_sane():
    d = tensorio.load(os.path.join(ART, "dataset.bin"))
    imgs, labels = d["images"], d["labels"]
    assert imgs.dtype == np.uint8 and labels.dtype == np.uint8
    assert imgs.shape[1:] == (28, 28)
    assert imgs.shape[0] == labels.shape[0] >= 1000
    assert labels.max() <= 9
    # all ten classes present
    assert len(np.unique(labels)) == 10
