"""Algorithm-1 preprocessor properties (numpy reference implementation).

The same invariants are property-tested on the rust side; this file pins
the semantics the two implementations must share.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import preprocess as pp


def rand_w(n, seed):
    return np.random.default_rng(seed).normal(size=n).astype(np.float32)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 200), rounding=st.floats(0, 2), seed=st.integers(0, 2**31 - 1))
def test_conservation(n, rounding, seed):
    """No weight is lost or duplicated: 2·pairs + unpaired = K."""
    w = rand_w(n, seed)
    p = pp.pair_filter(w, rounding)
    assert 2 * len(p.pair_k) + len(p.unp_w) == n
    used = sorted(p.pair_i1 + p.pair_i2 + p.unp_idx)
    assert used == list(range(n))


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 200), rounding=st.floats(0, 2), seed=st.integers(0, 2**31 - 1))
def test_pairs_within_rounding(n, rounding, seed):
    """Every combined pair satisfies | |Ka| − |Kb| | < rounding and the
    snapped magnitude is the mean, so the per-weight error < rounding/2."""
    w = rand_w(n, seed)
    p = pp.pair_filter(w, rounding)
    for i1, i2, k in zip(p.pair_i1, p.pair_i2, p.pair_k):
        ka, kb = w[i1], w[i2]
        assert ka > 0 and kb < 0
        assert abs(ka - (-kb)) < rounding
        assert abs(k - ka) <= rounding / 2 + 1e-6
        assert abs(k - (-kb)) <= rounding / 2 + 1e-6


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 100), rounding=st.floats(0.001, 2), seed=st.integers(0, 2**31 - 1))
def test_signs_preserved(n, rounding, seed):
    """Snapping never flips a weight's sign (k is a mean of two positives)."""
    w = rand_w(n, seed)
    wm = pp.modified_weights(w.reshape(1, -1), rounding).ravel()
    assert np.all(np.sign(wm) == np.sign(w))


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 120), seed=st.integers(0, 2**31 - 1))
def test_monotone_in_rounding(n, seed):
    """Larger rounding ⇒ at least as many pairs (Table 1 monotonicity)."""
    w = rand_w(n, seed)
    prev = -1
    for r in [0.0, 0.01, 0.05, 0.1, 0.3, 1.0, 10.0]:
        cur = len(pp.pair_filter(w, r).pair_k)
        assert cur >= prev
        prev = cur


def test_exact_opposites_snap_to_noop():
    """Weights that are already exact ± pairs (with magnitudes separated by
    more than `rounding`, so no cross-pairing is possible) must pass
    through the preprocessor unchanged — the snap is exact for them."""
    mags = np.array([0.2, 0.6, 1.0, 1.4], np.float32)  # gaps 0.4 > rounding
    w = np.concatenate([mags, -mags]).astype(np.float32)
    r = 0.1
    p = pp.pair_filter(w, r)
    assert len(p.pair_k) == len(mags)
    wm = pp.modified_weights(w.reshape(1, -1), r).ravel()
    np.testing.assert_array_equal(wm, w)


def test_second_pass_error_stays_bounded():
    """Pairing is not idempotent (a snapped weight may re-pair with a new
    partner) but each pass moves any weight by at most rounding/2, so two
    passes stay within rounding of the originals."""
    w = rand_w(60, 5)
    r = 0.1
    wm = pp.modified_weights(w.reshape(1, -1), r).ravel()
    wm2 = pp.modified_weights(wm.reshape(1, -1), r).ravel()
    assert np.abs(wm - w).max() <= r / 2 + 1e-6
    assert np.abs(wm2 - w).max() <= r + 1e-6
    # pair count never decreases on a snapped tensor
    assert len(pp.pair_filter(wm, r).pair_k) >= len(pp.pair_filter(w, r).pair_k)


def test_opcount_table1_semantics():
    """Op-count identity: adds = muls = base − subs; subs = pairs × usage."""
    w = rand_w(2 * 25, 9).reshape(2, 25)  # 2 filters of 25 weights
    r = 0.2
    pairs = sum(len(pp.pair_filter(w[c], r).pair_k) for c in range(2))
    usage = 49
    ops = pp.count_ops(w.reshape(2, 1, 5, 5), usage, r)
    base = 2 * 25 * usage
    assert ops["subs"] == pairs * usage
    assert ops["muls"] == ops["adds"] == base - ops["subs"]
    assert ops["total"] == 2 * base - ops["subs"]


def test_opcount_rounding_zero_lenet_c1():
    """LeNet C1 at rounding 0: 117 600 MACs (Table 1 decomposition)."""
    w = rand_w(6 * 25, 1).reshape(6, 1, 5, 5)
    ops = pp.count_ops(w, 28 * 28, 0.0)
    assert ops == {
        "adds": 117600, "subs": 0, "muls": 117600, "total": 235200
    }


def test_zero_weights_stay_uncombined():
    w = np.array([0.0, 0.5, -0.5, 0.0], np.float32)
    p = pp.pair_filter(w, 0.1)
    assert len(p.pair_k) == 1
    assert sorted(p.unp_idx) == [0, 3]
    assert all(v == 0.0 for i, v in zip(p.unp_idx, p.unp_w))


def test_boundary_exclusive():
    """Paper's conditions are ≥ / ≤: a gap of exactly `rounding` does NOT
    combine (strict interior required)."""
    w = np.array([0.5, -0.4], np.float32)
    # |0.5 - 0.4| = 0.1; rounding = 0.1 → PP.val >= |PN.val| + rounding → no pair
    p = pp.pair_filter(w, 0.1)
    assert len(p.pair_k) == 0
    p = pp.pair_filter(w, 0.1000001)
    assert len(p.pair_k) == 1
