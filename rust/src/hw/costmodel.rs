//! Per-operation cost constants for IEEE-754 single-precision units.
//!
//! The paper synthesizes IEEE-754 add / sub / mul units with Synopsys DC
//! on TSMC 65 nm at 1 GHz and reports *relative* savings. We cannot run
//! DC here, so we substitute published per-op costs and keep the ratios —
//! which fully determine the savings percentages — explicit:
//!
//! * Energy (Horowitz, ISSCC 2014, 45 nm): f32 add 0.9 pJ, f32 mul
//!   3.7 pJ → ratio ≈ 4.1. The paper's headline (32.03 % power saved at
//!   rounding 0.05 with 40.3 % of MACs paired) implies ratio ≈ 3.9 —
//!   inside the same band. Scaling 45 → 65 nm multiplies both by ≈ the
//!   same factor and cancels in every percentage we report.
//! * Area (same source): f32 add 4184 µm², f32 mul 7700 µm² → ratio 1.84
//!   (paper implies ≈ 1.6).
//! * A subtractor is an adder with a negated operand: cost(sub) =
//!   cost(add) — also the paper's premise.
//! * Delay at 1 GHz: both units are pipelined to 1 cycle; the PE
//!   simulator ([`super::pe`]) turns op mixes into cycles.


/// Cost of a single arithmetic operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    /// Energy per operation, picojoules.
    pub energy_pj: f64,
    /// Area of the functional unit, µm².
    pub area_um2: f64,
    /// Pipeline latency in cycles at the model frequency.
    pub latency_cycles: u32,
}

/// A full technology cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    pub name: &'static str,
    pub frequency_ghz: f64,
    pub add: OpCost,
    pub sub: OpCost,
    pub mul: OpCost,
}

impl CostModel {
    /// Default model: published 45 nm f32 numbers (ratios are what matter;
    /// see module docs). Frequency matches the paper's 1 GHz synthesis.
    pub fn ieee754_f32() -> Self {
        let add = OpCost { energy_pj: 0.9, area_um2: 4184.0, latency_cycles: 1 };
        CostModel {
            name: "ieee754-f32-45nm(horowitz-isscc14)",
            frequency_ghz: 1.0,
            add,
            sub: add, // subtractor == adder with operand negation
            mul: OpCost { energy_pj: 3.7, area_um2: 7700.0, latency_cycles: 1 },
        }
    }

    /// Variant calibrated so the rounding-0.05 row reproduces the paper's
    /// exact headline numbers (−32.03 % power, −24.59 % area at 40.30 %
    /// paired). Used by the fig8 bench to show sensitivity to the ratios.
    pub fn paper_calibrated() -> Self {
        let add = OpCost { energy_pj: 1.0, area_um2: 1000.0, latency_cycles: 1 };
        CostModel {
            name: "paper-calibrated-65nm",
            frequency_ghz: 1.0,
            add,
            sub: add,
            // energy ratio 3.87, area ratio 1.566 — back-solved from the
            // paper's 32.03 % / 24.59 % at pair fraction 0.40298
            mul: OpCost { energy_pj: 3.87, area_um2: 1566.0, latency_cycles: 1 },
        }
    }

    /// Energy of an op mix, picojoules.
    pub fn energy_pj(&self, adds: u64, subs: u64, muls: u64) -> f64 {
        adds as f64 * self.add.energy_pj
            + subs as f64 * self.sub.energy_pj
            + muls as f64 * self.mul.energy_pj
    }

    /// Datapath area for a unit mix (one functional unit per concurrent
    /// op slot), µm².
    pub fn area_um2(&self, add_units: u64, sub_units: u64, mul_units: u64) -> f64 {
        add_units as f64 * self.add.area_um2
            + sub_units as f64 * self.sub.area_um2
            + mul_units as f64 * self.mul.area_um2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mul_dominates_add() {
        for m in [CostModel::ieee754_f32(), CostModel::paper_calibrated()] {
            assert!(m.mul.energy_pj > 2.0 * m.add.energy_pj, "{}", m.name);
            assert!(m.mul.area_um2 > m.add.area_um2, "{}", m.name);
            assert_eq!(m.sub, m.add, "{}: sub must cost the same as add", m.name);
        }
    }

    #[test]
    fn energy_linear() {
        let m = CostModel::ieee754_f32();
        assert!((m.energy_pj(1, 0, 0) - 0.9).abs() < 1e-12);
        assert!((m.energy_pj(0, 1, 0) - 0.9).abs() < 1e-12);
        assert!((m.energy_pj(0, 0, 1) - 3.7).abs() < 1e-12);
        assert!((m.energy_pj(2, 3, 4) - (2.0 * 0.9 + 3.0 * 0.9 + 4.0 * 3.7)).abs() < 1e-9);
    }

    #[test]
    fn calibrated_ratios() {
        let m = CostModel::paper_calibrated();
        let rho_e = m.mul.energy_pj / m.add.energy_pj;
        let rho_a = m.mul.area_um2 / m.add.area_um2;
        // pair fraction at rounding 0.05 in the paper's Table 1
        let f = 163_447.0 / 405_600.0;
        let power_saving = f * rho_e / (1.0 + rho_e);
        let area_saving = f * rho_a / (1.0 + rho_a);
        assert!((power_saving - 0.3203).abs() < 0.002, "{power_saving}");
        assert!((area_saving - 0.2459).abs() < 0.002, "{area_saving}");
    }
}
