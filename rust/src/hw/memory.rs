//! Memory-hierarchy energy accounting — a system-level extension the
//! paper omits (its −32 % power is *datapath-only*).
//!
//! Accelerator energy is often dominated by data movement: with the
//! published per-access energies (Horowitz ISSCC'14, 45 nm: 8 KB SRAM
//! ≈ 10 pJ/32-bit word, DRAM ≈ 1.3–2.6 nJ/word), an honest system-level
//! savings number must include weight/activation traffic. The subtractor
//! method does not reduce *input* traffic (every `I` is still read), but
//! it does shrink weight storage (one `k` per pair instead of two full
//! weights) and therefore weight-buffer reads.
//!
//! [`MemoryModel::traffic`] derives per-inference traffic from a layer
//! pairing under a weight-stationary dataflow and prices it; combined
//! with the datapath cost this yields the *system-level* savings curve
//! (`benches/system_energy.rs`).

use super::costmodel::CostModel;
use crate::accel::LayerPairing;

/// Per-access energies, picojoules per 32-bit word.
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    /// On-chip SRAM (weight/activation buffers).
    pub sram_pj: f64,
    /// Off-chip DRAM.
    pub dram_pj: f64,
    /// Register-file / forwarding access (per operand reaching a lane).
    pub reg_pj: f64,
}

impl MemoryModel {
    /// Published 45 nm numbers (same source as the datapath constants).
    pub fn horowitz_45nm() -> Self {
        Self { sram_pj: 10.0, dram_pj: 1300.0, reg_pj: 1.0 }
    }
}

/// Traffic for one conv layer, in 32-bit-word accesses per inference.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Traffic {
    /// DRAM words: weights streamed once (weight-stationary) + ifmap once
    /// + ofmap once.
    pub dram_words: u64,
    /// SRAM words: weight-buffer reads + ifmap patch reads + ofmap writes.
    pub sram_words: u64,
    /// Register/operand events at the lanes.
    pub reg_words: u64,
}

impl Traffic {
    pub fn energy_pj(&self, m: &MemoryModel) -> f64 {
        self.dram_words as f64 * m.dram_pj
            + self.sram_words as f64 * m.sram_pj
            + self.reg_words as f64 * m.reg_pj
    }

    pub fn add(&mut self, o: Traffic) {
        self.dram_words += o.dram_words;
        self.sram_words += o.sram_words;
        self.reg_words += o.reg_words;
    }
}

/// Geometry the traffic model needs for one conv layer.
#[derive(Debug, Clone, Copy)]
pub struct LayerGeometry {
    /// Input feature-map words (C·H·W).
    pub ifmap_words: u64,
    /// Output feature-map words (Cout·OH·OW).
    pub ofmap_words: u64,
    /// Output positions (OH·OW).
    pub out_positions: u64,
}

/// Weight-stationary traffic for a paired layer.
///
/// Storage follows the paper's spliced layout (Fig 6): combined weights
/// sit at the top of the list as `k` + one packed index word (two 13-bit
/// patch indices fit LeNet's K ≤ 400), uncombined weights stay at the
/// bottom *positionally* (their patch order is preserved, so they need
/// no index metadata — they run through the ordinary MAC schedule).
/// Dense baseline stores K words per filter, positionally.
/// Per output position every stored weight word is read once from the
/// weight buffer; every pair gathers two input operands, every MAC one.
pub fn traffic(pairing: &LayerPairing, geo: LayerGeometry, dense: bool) -> Traffic {
    traffic_opt(pairing, geo, dense, false)
}

/// [`traffic`] with a residency knob: `weights_resident = true` models
/// weights pinned in on-chip SRAM (LeNet-5's 61 k parameters fit easily),
/// so DRAM carries only feature maps.
pub fn traffic_opt(
    pairing: &LayerPairing,
    geo: LayerGeometry,
    dense: bool,
    weights_resident: bool,
) -> Traffic {
    let pairs: u64 = pairing.filters.iter().map(|f| f.n_pairs() as u64).sum();
    let unpaired: u64 = pairing.filters.iter().map(|f| f.n_unpaired() as u64).sum();
    let total_weights = 2 * pairs + unpaired;

    let weight_words = if dense {
        total_weights // positional dense storage
    } else {
        // pair: k + packed index word; uncombined: positional value only
        2 * pairs + unpaired
    };
    let weight_reads_per_pos = weight_words;
    // operands reaching lanes per position: pair = 2 inputs + 1 k;
    // MAC = 1 input + 1 w; dense pair-equivalent = 2 MACs = 4 operands
    let reg_per_pos = if dense { 2 * total_weights } else { 3 * pairs + 2 * unpaired };

    Traffic {
        dram_words: if weights_resident { 0 } else { weight_words }
            + geo.ifmap_words
            + geo.ofmap_words,
        sram_words: weight_reads_per_pos * geo.out_positions
            + geo.ifmap_words // each ifmap word buffered once
            + geo.ofmap_words,
        reg_words: reg_per_pos * geo.out_positions,
    }
}

/// System-level energy: datapath + memory for one layer.
pub fn system_energy_pj(
    cost: &CostModel,
    mem: &MemoryModel,
    pairing: &LayerPairing,
    geo: LayerGeometry,
    dense: bool,
) -> f64 {
    system_energy_opt(cost, mem, pairing, geo, dense, false)
}

/// [`system_energy_pj`] with the weight-residency knob.
pub fn system_energy_opt(
    cost: &CostModel,
    mem: &MemoryModel,
    pairing: &LayerPairing,
    geo: LayerGeometry,
    dense: bool,
    weights_resident: bool,
) -> f64 {
    let pairs: u64 = pairing.filters.iter().map(|f| f.n_pairs() as u64).sum();
    let unpaired: u64 = pairing.filters.iter().map(|f| f.n_unpaired() as u64).sum();
    let total = 2 * pairs + unpaired;
    let datapath = if dense {
        cost.energy_pj(total * geo.out_positions, 0, total * geo.out_positions)
    } else {
        let macs = (pairs + unpaired) * geo.out_positions;
        cost.energy_pj(macs, pairs * geo.out_positions, macs)
    };
    datapath + traffic_opt(pairing, geo, dense, weights_resident).energy_pj(mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn pairing(rounding: f32) -> LayerPairing {
        // 4 weights: one exact pair + two loners
        let w = Tensor::new(&[1, 4], vec![0.5, -0.5, 0.9, 0.2]);
        LayerPairing::from_weights(&w, rounding)
    }

    const GEO: LayerGeometry =
        LayerGeometry { ifmap_words: 100, ofmap_words: 50, out_positions: 10 };

    #[test]
    fn unpaired_weights_are_positional() {
        // 1 pair + 2 loners: dense 4 words; paired 2 (k + index) + 2 = 4 —
        // the index word exactly offsets the merged pair value.
        let p = pairing(0.01);
        assert_eq!(p.total_pairs(), 1);
        let dense = traffic(&p, GEO, true);
        let paired = traffic(&p, GEO, false);
        assert_eq!(paired.dram_words, dense.dram_words);
        // register/operand traffic shrinks: pair = 3 operands vs 4
        assert!(paired.reg_words < dense.reg_words);
    }

    #[test]
    fn full_pairing_keeps_storage_parity_and_cuts_operands() {
        let w = Tensor::new(&[1, 6], vec![0.5, -0.5, 0.3, -0.3, 0.7, -0.7]);
        let p = LayerPairing::from_weights(&w, 0.01);
        assert_eq!(p.total_pairs(), 3);
        let dense = traffic(&p, GEO, true);
        let paired = traffic(&p, GEO, false);
        // dense 6 words vs paired 3·2 = 6 words — parity at 100 % pairing
        assert_eq!(paired.dram_words, dense.dram_words);
        // register traffic shrinks: 3 pairs × 3 operands < 6 MACs × 2
        assert!(paired.reg_words < dense.reg_words);
    }

    #[test]
    fn energy_is_positive_and_memory_dominates_for_small_compute() {
        let cost = CostModel::ieee754_f32();
        let mem = MemoryModel::horowitz_45nm();
        let p = pairing(0.01);
        let e = system_energy_pj(&cost, &mem, &p, GEO, true);
        assert!(e > 0.0);
        let t = traffic(&p, GEO, true);
        assert!(t.energy_pj(&mem) > 0.5 * e, "DRAM should dominate tiny layers");
    }

    #[test]
    fn system_savings_smaller_than_datapath_savings() {
        // the paper's headline is datapath-only; with memory included the
        // relative saving must shrink (memory traffic barely changes)
        let cost = CostModel::ieee754_f32();
        let mem = MemoryModel::horowitz_45nm();
        let w = Tensor::new(
            &[1, 100],
            (0..100).map(|i| if i % 2 == 0 { 0.1 + i as f32 * 1e-3 } else { -(0.1 + (i - 1) as f32 * 1e-3) }).collect(),
        );
        let p = LayerPairing::from_weights(&w, 0.01);
        assert!(p.total_pairs() >= 45);
        let geo = LayerGeometry { ifmap_words: 1000, ofmap_words: 500, out_positions: 500 };
        let dense_dp = {
            let total = 100 * geo.out_positions;
            cost.energy_pj(total, 0, total)
        };
        let paired_dp = {
            let pairs: u64 = p.total_pairs() as u64;
            let unp = 100 - 2 * pairs;
            let macs = (pairs + unp) * geo.out_positions;
            cost.energy_pj(macs, pairs * geo.out_positions, macs)
        };
        let dp_saving = 1.0 - paired_dp / dense_dp;
        let sys_dense = system_energy_pj(&cost, &mem, &p, geo, true);
        let sys_paired = system_energy_pj(&cost, &mem, &p, geo, false);
        let sys_saving = 1.0 - sys_paired / sys_dense;
        assert!(sys_saving < dp_saving, "system {sys_saving} !< datapath {dp_saving}");
        assert!(sys_saving > 0.0, "still a net win at high pair fraction");
    }
}
