//! Virtual synthesis: turn an op mix (a reproduced Table-1 row) into
//! accelerator power / area and savings vs the dense baseline — the
//! machinery behind the Fig-8 left axis.
//!
//! Model (matches how the paper frames its DC results):
//!
//! * **Power** ∝ energy per inference at fixed frequency & throughput:
//!   `E = n_add·E_add + n_sub·E_sub + n_mul·E_mul`.
//! * **Area** ∝ functional-unit count at fixed throughput. A dense design
//!   needs one (mul, add) slot per MAC of sustained throughput; the
//!   modified unit replaces a fraction of those slots with (sub) slots —
//!   unit counts scale with the per-inference op mix.

use super::costmodel::CostModel;
use crate::accel::ModelOps;

/// Synthesis output for one design point.
#[derive(Debug, Clone)]
pub struct SynthesisResult {
    pub rounding: f32,
    /// Energy per inference, nanojoules.
    pub energy_nj: f64,
    /// Mean power at the model frequency assuming fully-pipelined units
    /// (one op per unit per cycle), milliwatts.
    pub power_mw: f64,
    /// Datapath area, mm², for a throughput-normalized unit mix.
    pub area_mm2: f64,
    /// Cycles per inference on the throughput-normalized array.
    pub cycles: u64,
}

/// Savings of a design point vs the dense (rounding = 0) baseline.
#[derive(Debug, Clone)]
pub struct SavingsReport {
    pub rounding: f32,
    pub power_saving_pct: f64,
    pub area_saving_pct: f64,
    pub ops_saving_pct: f64,
}

/// Number of parallel op slots the virtual array sustains; cancels in all
/// savings percentages, only sets absolute power/area scale.
const ARRAY_SLOTS: u64 = 64;

/// Synthesize one design point from an op-count row.
pub fn synthesize(model: &CostModel, ops: &ModelOps) -> SynthesisResult {
    let energy_pj = model.energy_pj(ops.adds, ops.subs, ops.muls);
    let total_ops = ops.adds + ops.subs + ops.muls;
    let cycles = total_ops.div_ceil(ARRAY_SLOTS);
    // time per inference at f GHz: cycles / (f·1e9) s
    let secs = cycles as f64 / (model.frequency_ghz * 1e9);
    let power_mw = (energy_pj * 1e-12) / secs * 1e3;
    // throughput-normalized unit mix: slots split in proportion to op mix
    let t = total_ops as f64;
    let area_um2 = model.area_um2(
        ((ops.adds as f64 / t) * ARRAY_SLOTS as f64).round() as u64,
        ((ops.subs as f64 / t) * ARRAY_SLOTS as f64).round() as u64,
        ((ops.muls as f64 / t) * ARRAY_SLOTS as f64).round() as u64,
    );
    SynthesisResult {
        rounding: ops.rounding,
        energy_nj: energy_pj * 1e-3,
        power_mw,
        area_mm2: area_um2 * 1e-6,
        cycles,
    }
}

/// Savings vs baseline, in the percentages Fig 8 plots.
///
/// Both power and area savings reduce to closed forms independent of the
/// array size:  `saving = f · ρ / (1 + ρ)` with `f` the paired MAC
/// fraction and `ρ` the mul/add cost ratio — that closed form is what the
/// cost-model unit tests pin against the paper's headline numbers.
pub fn savings(model: &CostModel, baseline: &ModelOps, point: &ModelOps) -> SavingsReport {
    let e0 = model.energy_pj(baseline.adds, baseline.subs, baseline.muls);
    let e1 = model.energy_pj(point.adds, point.subs, point.muls);
    // area: unit mix in op proportions, exact (not slot-rounded) for the
    // percentage so tiny roundings don't wiggle the curve
    let a = |o: &ModelOps| {
        o.adds as f64 * model.add.area_um2
            + o.subs as f64 * model.sub.area_um2
            + o.muls as f64 * model.mul.area_um2
    };
    let (a0, a1) = (a(baseline), a(point));
    let (t0, t1) = (baseline.total as f64, point.total as f64);
    SavingsReport {
        rounding: point.rounding,
        power_saving_pct: (1.0 - e1 / e0) * 100.0,
        area_saving_pct: (1.0 - a1 / a0) * 100.0,
        ops_saving_pct: (1.0 - t1 / t0) * 100.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(rounding: f32, subs: u64) -> ModelOps {
        let macs = 405_600 - subs;
        ModelOps {
            rounding,
            adds: macs,
            subs,
            muls: macs,
            total: 2 * macs + subs,
            layers: vec![],
        }
    }

    #[test]
    fn baseline_has_zero_savings() {
        let m = CostModel::ieee754_f32();
        let b = row(0.0, 0);
        let s = savings(&m, &b, &b);
        assert_eq!(s.power_saving_pct, 0.0);
        assert_eq!(s.area_saving_pct, 0.0);
        assert_eq!(s.ops_saving_pct, 0.0);
    }

    #[test]
    fn paper_headline_row_with_calibrated_model() {
        // Table-1 rounding-0.05 row: 163447 subs → paper's −32.03 % / −24.59 %
        let m = CostModel::paper_calibrated();
        let s = savings(&m, &row(0.0, 0), &row(0.05, 163_447));
        assert!((s.power_saving_pct - 32.03).abs() < 0.2, "{}", s.power_saving_pct);
        assert!((s.area_saving_pct - 24.59).abs() < 0.2, "{}", s.area_saving_pct);
        // total-ops saving for that row: 1 − 647753/811200 = 20.15 %
        assert!((s.ops_saving_pct - 20.15).abs() < 0.1, "{}", s.ops_saving_pct);
    }

    #[test]
    fn horowitz_model_is_in_band() {
        // with the published 45 nm ratios the same row gives ~32–33 % power
        // and ~26 % area — the shape the reproduction must land in
        let m = CostModel::ieee754_f32();
        let s = savings(&m, &row(0.0, 0), &row(0.05, 163_447));
        assert!(s.power_saving_pct > 28.0 && s.power_saving_pct < 36.0);
        assert!(s.area_saving_pct > 20.0 && s.area_saving_pct < 30.0);
    }

    #[test]
    fn savings_monotone_in_subs() {
        let m = CostModel::ieee754_f32();
        let b = row(0.0, 0);
        let mut prev = -1.0;
        for subs in [0u64, 50_000, 100_000, 163_447, 182_858] {
            let s = savings(&m, &b, &row(0.1, subs));
            assert!(s.power_saving_pct >= prev);
            prev = s.power_saving_pct;
        }
    }

    #[test]
    fn synthesize_absolute_numbers_sane() {
        let m = CostModel::ieee754_f32();
        let r = synthesize(&m, &row(0.0, 0));
        assert!(r.energy_nj > 0.0);
        assert!(r.power_mw > 0.0);
        assert!(r.area_mm2 > 0.0);
        assert_eq!(r.cycles, (811_200u64).div_ceil(64));
        // paired point strictly cheaper
        let p = synthesize(&m, &row(0.05, 163_447));
        assert!(p.energy_nj < r.energy_nj);
        assert!(p.cycles < r.cycles);
    }
}
