//! Cycle-level simulator of the modified convolution unit (paper Fig 5).
//!
//! The unit has two lane types:
//!
//! * **MAC lanes** — multiply + accumulate, one uncombined weight per
//!   cycle per lane;
//! * **subtractor lanes** — subtract + multiply + accumulate, one combined
//!   *pair* per cycle per lane (the paper's fused `k·(I1−I2)` datapath).
//!
//! For each output position of each filter, the pair work and the MAC
//! work issue in parallel across their lanes; the position completes when
//! the slower side finishes. This gives cycles-per-inference and lane
//! utilization for any pairing, letting the delay/throughput side of the
//! paper's claims be sanity-checked (the paper reports power/area only;
//! we additionally show the schedule does not lengthen).

use crate::accel::LayerPairing;

/// Array configuration.
#[derive(Debug, Clone, Copy)]
pub struct PeArrayConfig {
    pub mac_lanes: usize,
    pub sub_lanes: usize,
    /// Clock, GHz (the paper synthesizes at 1 GHz).
    pub frequency_ghz: f64,
}

impl Default for PeArrayConfig {
    fn default() -> Self {
        // a modest edge-accelerator array; savings percentages are
        // config-independent, absolute latency is not
        Self { mac_lanes: 16, sub_lanes: 8, frequency_ghz: 1.0 }
    }
}

/// Simulation result for one layer (or one accumulated model).
#[derive(Debug, Clone, Default)]
pub struct PeReport {
    pub cycles: u64,
    /// Busy lane-cycles / available lane-cycles.
    pub mac_utilization: f64,
    pub sub_utilization: f64,
    /// Latency at the configured clock, microseconds.
    pub latency_us: f64,
}

/// The simulator.
#[derive(Debug, Clone, Copy)]
pub struct PeArraySim {
    pub config: PeArrayConfig,
}

impl PeArraySim {
    pub fn new(config: PeArrayConfig) -> Self {
        assert!(config.mac_lanes > 0, "need at least one MAC lane");
        Self { config }
    }

    /// Simulate one conv layer: every filter × every output position
    /// issues its pair work on the sub lanes and its uncombined work on
    /// the MAC lanes.
    pub fn simulate_layer(&self, pairing: &LayerPairing, out_positions: usize) -> PeReport {
        let mut cycles = 0u64;
        let mut mac_busy = 0u64;
        let mut sub_busy = 0u64;
        for f in &pairing.filters {
            let pairs = f.n_pairs() as u64;
            let unp = f.n_unpaired() as u64;
            // per output position: both lane groups run concurrently
            let sub_cycles = if self.config.sub_lanes > 0 {
                pairs.div_ceil(self.config.sub_lanes as u64)
            } else {
                // no subtractor lanes: pairs fall back to 2 MAC ops each
                0
            };
            let mac_ops = if self.config.sub_lanes > 0 { unp } else { unp + 2 * pairs };
            let mac_cycles = mac_ops.div_ceil(self.config.mac_lanes as u64);
            let per_pos = sub_cycles.max(mac_cycles).max(1);
            cycles += per_pos * out_positions as u64;
            mac_busy += mac_ops * out_positions as u64;
            sub_busy += if self.config.sub_lanes > 0 { pairs * out_positions as u64 } else { 0 };
        }
        let mac_avail = cycles * self.config.mac_lanes as u64;
        let sub_avail = cycles * self.config.sub_lanes as u64;
        PeReport {
            cycles,
            mac_utilization: if mac_avail > 0 { mac_busy as f64 / mac_avail as f64 } else { 0.0 },
            sub_utilization: if sub_avail > 0 { sub_busy as f64 / sub_avail as f64 } else { 0.0 },
            latency_us: cycles as f64 / (self.config.frequency_ghz * 1e3),
        }
    }

    /// Simulate a list of `(pairing, out_positions)` layers back-to-back.
    pub fn simulate_model(&self, layers: &[(&LayerPairing, usize)]) -> PeReport {
        let mut total = PeReport::default();
        let mut mac_busy_cycles = 0.0;
        let mut sub_busy_cycles = 0.0;
        for (p, pos) in layers {
            let r = self.simulate_layer(p, *pos);
            mac_busy_cycles += r.mac_utilization * r.cycles as f64;
            sub_busy_cycles += r.sub_utilization * r.cycles as f64;
            total.cycles += r.cycles;
            total.latency_us += r.latency_us;
        }
        if total.cycles > 0 {
            total.mac_utilization = mac_busy_cycles / total.cycles as f64;
            total.sub_utilization = sub_busy_cycles / total.cycles as f64;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn pairing(weights: Vec<f32>, cout: usize, rounding: f32) -> LayerPairing {
        let k = weights.len() / cout;
        LayerPairing::from_weights(&Tensor::new(&[cout, 1, 1, k], weights), rounding)
    }

    #[test]
    fn dense_layer_cycles() {
        // 1 filter, 16 uncombined weights, 16 MAC lanes → 1 cycle/position
        let p = pairing((1..=16).map(|i| i as f32).collect(), 1, 0.0);
        let sim = PeArraySim::new(PeArrayConfig { mac_lanes: 16, sub_lanes: 8, frequency_ghz: 1.0 });
        let r = sim.simulate_layer(&p, 100);
        assert_eq!(r.cycles, 100);
        assert!((r.mac_utilization - 1.0).abs() < 1e-9);
        assert_eq!(r.sub_utilization, 0.0);
    }

    #[test]
    fn paired_layer_fewer_cycles_than_dense() {
        // 32 weights forming 16 exact pairs: dense needs 2 cycles/pos on
        // 16 MAC lanes; paired needs ⌈16/8⌉ = 2 sub-cycles but 0 MAC — tie;
        // with 16 sub lanes it halves.
        let mut w: Vec<f32> = Vec::new();
        for i in 1..=16 {
            w.push(i as f32);
            w.push(-(i as f32));
        }
        let p = pairing(w, 1, 0.001);
        assert_eq!(p.total_pairs(), 16);
        let dense_cfg = PeArraySim::new(PeArrayConfig { mac_lanes: 16, sub_lanes: 0, frequency_ghz: 1.0 });
        let sub_cfg = PeArraySim::new(PeArrayConfig { mac_lanes: 16, sub_lanes: 16, frequency_ghz: 1.0 });
        let dense = dense_cfg.simulate_layer(&p, 10);
        let paired = sub_cfg.simulate_layer(&p, 10);
        assert_eq!(dense.cycles, 20);
        assert_eq!(paired.cycles, 10);
    }

    #[test]
    fn no_sub_lanes_falls_back_to_macs() {
        let p = pairing(vec![1.0, -1.0, 0.5, -0.5], 1, 0.01);
        let sim = PeArraySim::new(PeArrayConfig { mac_lanes: 4, sub_lanes: 0, frequency_ghz: 1.0 });
        let r = sim.simulate_layer(&p, 5);
        // 2 pairs → 4 MAC ops per position → 1 cycle on 4 lanes
        assert_eq!(r.cycles, 5);
        assert_eq!(r.sub_utilization, 0.0);
    }

    #[test]
    fn latency_scales_with_frequency() {
        let p = pairing(vec![1.0; 8], 1, 0.0);
        let r1 = PeArraySim::new(PeArrayConfig { mac_lanes: 8, sub_lanes: 0, frequency_ghz: 1.0 })
            .simulate_layer(&p, 100);
        let r2 = PeArraySim::new(PeArrayConfig { mac_lanes: 8, sub_lanes: 0, frequency_ghz: 2.0 })
            .simulate_layer(&p, 100);
        assert_eq!(r1.cycles, r2.cycles);
        assert!((r1.latency_us - 2.0 * r2.latency_us).abs() < 1e-9);
    }

    #[test]
    fn model_accumulation() {
        let p1 = pairing(vec![1.0; 8], 1, 0.0);
        let p2 = pairing(vec![1.0, -1.0], 1, 0.01);
        let sim = PeArraySim::new(PeArrayConfig::default());
        let r = sim.simulate_model(&[(&p1, 10), (&p2, 20)]);
        let a = sim.simulate_layer(&p1, 10);
        let b = sim.simulate_layer(&p2, 20);
        assert_eq!(r.cycles, a.cycles + b.cycles);
    }
}
