//! Int8 quantized datapath — extension beyond the paper's IEEE-754 units.
//!
//! Edge accelerators overwhelmingly run int8; the natural question is
//! whether the subtractor substitution still pays. It pays *more*: the
//! published int8 cost ratios (mul/add ≈ 6.7× energy, ≈ 7.8× area vs
//! ≈ 4.1× / 1.84× for f32) make each converted multiply worth more. The
//! subtractor identity survives quantization exactly: symmetric int8
//! quantization maps the snapped pair (k, −k) to (q, −q), so
//! `q·(I1 − I2)` remains bit-exact vs the quantized dense conv.
//!
//! This module provides symmetric per-tensor int8 quantization, a
//! quantized paired-conv unit ([`QuantSubConv2d`]), and the int8 cost
//! model. `benches/system_energy.rs` reports the int8 savings curve.

use super::costmodel::{CostModel, OpCost};
use crate::accel::LayerPairing;
use crate::nn::OpCounts;
use crate::tensor::{im2col, Tensor};

/// Symmetric per-tensor quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Real value = scale × int8 value.
    pub scale: f32,
}

impl QuantParams {
    /// Fit the scale so `max |v|` maps to ±127.
    pub fn fit(values: &[f32]) -> Self {
        let max = values.iter().fold(0f32, |a, &v| a.max(v.abs()));
        Self { scale: if max > 0.0 { max / 127.0 } else { 1.0 } }
    }

    #[inline]
    pub fn quantize(&self, v: f32) -> i8 {
        (v / self.scale).round().clamp(-127.0, 127.0) as i8
    }

    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        q as f32 * self.scale
    }
}

/// An int8 tensor with its quantization params.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
    pub params: QuantParams,
}

/// Quantize an f32 tensor symmetrically.
pub fn quantize_tensor(t: &Tensor) -> QuantizedTensor {
    let params = QuantParams::fit(t.data());
    QuantizedTensor {
        shape: t.shape().to_vec(),
        data: t.data().iter().map(|&v| params.quantize(v)).collect(),
        params,
    }
}

/// Dequantize back to f32.
pub fn dequantize(q: &QuantizedTensor) -> Tensor {
    Tensor::new(&q.shape, q.data.iter().map(|&v| q.params.dequantize(v)).collect())
}

impl CostModel {
    /// Int8 unit costs (Horowitz ISSCC'14, 45 nm): add 0.03 pJ / 36 µm²,
    /// mul 0.2 pJ / 282 µm². Ratios 6.7× energy, 7.8× area — the
    /// subtractor trade gets *better* at int8.
    pub fn int8() -> Self {
        let add = OpCost { energy_pj: 0.03, area_um2: 36.0, latency_cycles: 1 };
        CostModel {
            name: "int8-45nm(horowitz-isscc14)",
            frequency_ghz: 1.0,
            add,
            sub: add,
            mul: OpCost { energy_pj: 0.2, area_um2: 282.0, latency_cycles: 1 },
        }
    }
}

/// Quantized paired conv layer: int8 operands, i32 accumulation, f32
/// bias/dequant at the end (the standard int8 inference recipe).
#[derive(Debug, Clone)]
pub struct QuantSubConv2d {
    pairing: LayerPairing,
    /// Quantized snapped weights per filter: pairs as q (i2 implied −q),
    /// uncombined as raw int8.
    pair_q: Vec<Vec<i8>>,
    unp_q: Vec<Vec<i8>>,
    wparams: QuantParams,
    bias: Tensor,
    kh: usize,
    kw: usize,
    cout: usize,
}

impl QuantSubConv2d {
    /// Pair in f32 (Algorithm 1), snap, then quantize the snapped weights.
    pub fn compile(weight: &Tensor, bias: &Tensor, rounding: f32) -> Self {
        let pairing = LayerPairing::from_weights(weight, rounding);
        let modified = pairing.modified_weights(weight);
        let wparams = QuantParams::fit(modified.data());
        let cout = weight.shape()[0];
        let mut pair_q = Vec::with_capacity(cout);
        let mut unp_q = Vec::with_capacity(cout);
        for f in &pairing.filters {
            pair_q.push(f.pair_k.iter().map(|&k| wparams.quantize(k)).collect());
            unp_q.push(f.unp_w.iter().map(|&w| wparams.quantize(w)).collect());
        }
        Self {
            pairing,
            pair_q,
            unp_q,
            wparams,
            bias: bias.clone(),
            kh: weight.shape()[2],
            kw: weight.shape()[3],
            cout,
        }
    }

    pub fn total_pairs(&self) -> usize {
        self.pairing.total_pairs()
    }

    /// f32 in → quantize activations → int8 paired conv → f32 out.
    pub fn forward(&self, x: &Tensor) -> (Tensor, OpCounts) {
        let ic = im2col(x, self.kh, self.kw);
        let rows = ic.patches.shape()[0];
        let k = ic.k;
        let xparams = QuantParams::fit(ic.patches.data());
        let xq: Vec<i8> = ic.patches.data().iter().map(|&v| xparams.quantize(v)).collect();
        let out_scale = xparams.scale * self.wparams.scale;

        let mut out = vec![0f32; rows * self.cout];
        for r in 0..rows {
            let patch = &xq[r * k..(r + 1) * k];
            for (c, f) in self.pairing.filters.iter().enumerate() {
                let mut acc: i32 = 0;
                // subtractor lane in the int8 domain: q·(I1 − I2)
                for (j, &q) in self.pair_q[c].iter().enumerate() {
                    let d = patch[f.pair_i1[j] as usize] as i32
                        - patch[f.pair_i2[j] as usize] as i32;
                    acc += q as i32 * d;
                }
                for (j, &q) in self.unp_q[c].iter().enumerate() {
                    acc += q as i32 * patch[f.unp_idx[j] as usize] as i32;
                }
                out[r * self.cout + c] = acc as f32 * out_scale + self.bias.data()[c];
            }
        }

        let (b, oh, ow) = (ic.batch, ic.out_h, ic.out_w);
        let mut nchw = vec![0f32; out.len()];
        for bi in 0..b {
            for y in 0..oh {
                for xw in 0..ow {
                    let r = (bi * oh + y) * ow + xw;
                    for c in 0..self.cout {
                        nchw[((bi * self.cout + c) * oh + y) * ow + xw] =
                            out[r * self.cout + c];
                    }
                }
            }
        }
        let pairs = self.pairing.total_pairs() as u64;
        let unpaired: u64 = self.pairing.filters.iter().map(|f| f.n_unpaired() as u64).sum();
        let counts = OpCounts::paired_layer(pairs, unpaired, (b * oh * ow) as u64, 0);
        (Tensor::new(&[b, self.cout, oh, ow], nchw), counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Rng::seed_from_u64(1);
        let t = Tensor::new(&[100], rng.vec_range(100, -2.0, 2.0));
        let q = quantize_tensor(&t);
        let back = dequantize(&q);
        // symmetric int8: error ≤ scale/2
        assert!(t.max_abs_diff(&back) <= q.params.scale / 2.0 + 1e-7);
    }

    #[test]
    fn snapped_pairs_stay_exact_opposites_in_int8() {
        let p = QuantParams::fit(&[0.73, -0.73, 0.2]);
        assert_eq!(p.quantize(0.73), -p.quantize(-0.73));
    }

    #[test]
    fn zero_tensor_quantizes_safely() {
        let q = quantize_tensor(&Tensor::zeros(&[5]));
        assert!(q.data.iter().all(|&v| v == 0));
        assert_eq!(q.params.scale, 1.0);
    }

    #[test]
    fn quantized_paired_close_to_f32_dense() {
        let mut rng = Rng::seed_from_u64(4);
        let x = Tensor::new(&[1, 3, 8, 8], rng.vec_range(3 * 64, -1.0, 1.0));
        let w = Tensor::new(&[4, 3, 3, 3], rng.vec_range(4 * 27, -0.5, 0.5));
        let b = Tensor::new(&[4], rng.vec_range(4, -0.1, 0.1));
        let unit = QuantSubConv2d::compile(&w, &b, 0.05);
        let (got, counts) = unit.forward(&x);
        let wmod = LayerPairing::from_weights(&w, 0.05).modified_weights(&w);
        let (want, _) = crate::nn::layers::conv2d(&x, &wmod, &b, 1, 0);
        // int8 error bound: K·(qx·qw cross terms) — loose practical bound
        assert!(
            got.max_abs_diff(&want) < 0.2,
            "int8 drifted too far: {}",
            got.max_abs_diff(&want)
        );
        assert!(counts.subs > 0);
    }

    #[test]
    fn int8_model_ratios() {
        let m = CostModel::int8();
        assert!(m.mul.energy_pj / m.add.energy_pj > 6.0);
        assert!(m.mul.area_um2 / m.add.area_um2 > 7.0);
        assert_eq!(m.sub, m.add);
    }

    #[test]
    fn int8_savings_exceed_f32_savings() {
        // same pair fraction, higher mul/add ratio → larger saving
        use crate::hw::savings_report;
        let row = |r: f32, subs: u64| crate::accel::ModelOps {
            rounding: r,
            adds: 405_600 - subs,
            subs,
            muls: 405_600 - subs,
            total: 811_200 - subs,
            layers: vec![],
        };
        let base = row(0.0, 0);
        let point = row(0.05, 163_447);
        let f32_s = savings_report(&CostModel::ieee754_f32(), &base, &point);
        let i8_s = savings_report(&CostModel::int8(), &base, &point);
        assert!(i8_s.power_saving_pct > f32_s.power_saving_pct);
        assert!(i8_s.area_saving_pct > f32_s.area_saving_pct);
    }
}
