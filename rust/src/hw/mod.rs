//! Hardware cost modelling — the substitute for the paper's Synopsys
//! Design Compiler + TSMC 65 nm flow (DESIGN.md §3).
//!
//! * [`costmodel`] — per-operation energy / area / delay constants for
//!   IEEE-754 f32 add / sub / mul units, with the ratios that drive the
//!   paper's savings documented and sourced.
//! * [`synthesis`] — "virtual synthesis": composes op mixes into
//!   accelerator power / area and computes savings vs the dense baseline
//!   (reproduces Fig 8's left axis).
//! * [`pe`] — cycle-level simulator of the modified convolution unit
//!   (paper Fig 5): subtractor lanes + MAC lanes over the pairing
//!   schedule, reporting cycles and lane utilization.

mod costmodel;
mod memory;
mod pe;
mod quant;
mod synthesis;

pub use costmodel::{CostModel, OpCost};
pub use memory::{
    system_energy_opt, system_energy_pj, traffic, traffic_opt, LayerGeometry, MemoryModel,
    Traffic,
};
pub use quant::{dequantize, quantize_tensor, QuantParams, QuantSubConv2d, QuantizedTensor};
pub use pe::{PeArrayConfig, PeArraySim, PeReport};
pub use synthesis::{savings as savings_report, synthesize, SavingsReport, SynthesisResult};
