//! # subaccel — Subtractor-Based CNN Inference Accelerator
//!
//! Production-quality reproduction of *"Subtractor-Based CNN Inference
//! Accelerator"* (Gao, Hammad, El-Sankary, Gu — CS.AR 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serving coordinator, the paper's weight
//!   preprocessor (Algorithm 1), the modified convolution unit, the
//!   hardware cost model that substitutes for Synopsys DC + TSMC 65 nm,
//!   and a pure-rust CNN engine used as a second numerical oracle.
//! * **L2/L1 (python/, build-time only)** — LeNet-5 in JAX calling Pallas
//!   kernels, AOT-lowered to HLO text in `artifacts/` and executed here
//!   through the PJRT CPU client ([`runtime`]). Python never runs on the
//!   request path.
//!
//! Module map (see DESIGN.md for the experiment index):
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | minimal f32 NCHW tensor substrate |
//! | [`nn`] | pure-rust CNN inference engine + LeNet-5/AlexNet defs |
//! | [`data`] | tensor container I/O + datasets (wire contract with python) |
//! | [`accel`] | **the paper**: Algorithm 1, subtractor conv unit, op counts |
//! | [`hw`] | 65 nm IEEE-754 cost model, virtual synthesis, PE simulator |
//! | [`runtime`] | PJRT: load `artifacts/*.hlo.txt`, compile, execute |
//! | [`coordinator`] | async request router + dynamic batcher + metrics |

pub mod accel;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod metrics;
pub mod nn;
pub mod runtime;
pub mod tensor;
pub mod util;
