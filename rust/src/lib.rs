//! # subaccel — Subtractor-Based CNN Inference Accelerator
//!
//! Production-quality reproduction of *"Subtractor-Based CNN Inference
//! Accelerator"* (Gao, Hammad, El-Sankary, Gu — CS.AR 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serving coordinator, the paper's weight
//!   preprocessor (Algorithm 1), the modified convolution unit and its
//!   multi-threaded packed execution engine, the hardware cost model
//!   that substitutes for Synopsys DC + TSMC 65 nm, and a pure-rust CNN
//!   engine used as a second numerical oracle.
//! * **L2/L1 (python/, build-time only)** — LeNet-5 in JAX calling Pallas
//!   kernels, AOT-lowered to HLO text in `artifacts/` and executed here
//!   through the PJRT CPU client ([`runtime`]). Python never runs on the
//!   request path.
//!
//! ## Public API shape
//!
//! * Configuration is *validated at construction*:
//!   [`coordinator::ServeConfig::builder`] rejects inconsistent
//!   combinations (zero workers, queue smaller than a batch, batch sizes
//!   with no compiled artifact) before any thread spawns.
//! * Fallible library calls return the typed [`error::SubaccelError`];
//!   `anyhow` appears only at binary/example edges.
//! * Serving observability is structured:
//!   [`metrics::ServerMetrics::snapshot`] returns a
//!   [`metrics::MetricsSnapshot`] (counters + latency percentiles) whose
//!   `Display` impl is the human-readable summary line.
//! * The hot path is [`accel::ConvEngine`]: a persistent worker pool
//!   executing [`accel::PackedPairing`] (structure-of-arrays pairing
//!   tables) over im2col row shards, bit-identical across thread counts.
//! * Whole-network inference follows a *plan/execute split*
//!   ([`exec::ExecutionPlan`]): Algorithm 1 and all layer geometry are
//!   resolved at compile time into a plan whose executor runs the full
//!   network with zero steady-state allocations; `nn`, `runtime`, and
//!   `coordinator` all serve through it (see ARCHITECTURE.md).
//!
//! Module map (see DESIGN.md for the experiment index):
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | minimal f32 NCHW tensor substrate + reusable im2col |
//! | [`nn`] | pure-rust CNN inference engine + LeNet-5/AlexNet defs + [`nn::PairedModel`] |
//! | [`data`] | tensor container I/O + datasets (wire contract with python) |
//! | [`accel`] | **the paper**: Algorithm 1, subtractor conv unit, packed parallel engine, op counts |
//! | [`exec`] | plan/execute split: compile models into zero-alloc whole-network execution plans |
//! | [`hw`] | 65 nm IEEE-754 cost model, virtual synthesis, PE simulator |
//! | [`runtime`] | PJRT: load `artifacts/*.hlo.txt`, compile, execute; CPU paired executor |
//! | [`coordinator`] | async request router + dynamic batcher + backend selection |
//! | [`metrics`] | lock-free serving counters + log-bucketed latency histograms |
//! | [`error`] | [`error::SubaccelError`] — the crate-wide typed error enum |
//! | [`util`] | in-tree PRNG, property-test harness, bench loop, temp dirs |

pub mod accel;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod exec;
pub mod hw;
pub mod metrics;
pub mod nn;
pub mod runtime;
pub mod tensor;
pub mod util;
