//! Minimal property-testing loop (stand-in for `proptest`): generate N
//! random cases from a seeded [`Gen`], run the property, and on failure
//! report the case index + seed so the exact case replays.

use super::rng::Rng;

/// Case generator handed to properties: a seeded RNG plus the case index.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Random vector of weights in [-scale, scale] with random length in
    /// [1, max_len] — the common input shape for pairing properties.
    pub fn weights(&mut self, max_len: usize, scale: f32) -> Vec<f32> {
        let n = 1 + self.rng.below(max_len);
        self.rng.vec_range(n, -scale, scale)
    }
}

/// Run `prop` over `cases` generated cases. Panics with a replayable
/// message on the first failing case (properties signal failure by
/// returning `Err(reason)`).
pub fn forall(name: &str, seed: u64, cases: usize, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        // derive an independent stream per case so failures replay alone
        let mut g = Gen { rng: Rng::seed_from_u64(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15)), case };
        if let Err(reason) = prop(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed}): {reason}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("count", 1, 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'bad' failed at case 3")]
    fn failing_property_reports_case() {
        forall("bad", 1, 10, |g| if g.case == 3 { Err("boom".into()) } else { Ok(()) });
    }

    #[test]
    fn weights_within_bounds() {
        forall("weights-gen", 2, 20, |g| {
            let w = g.weights(50, 0.5);
            if w.is_empty() || w.len() > 50 {
                return Err(format!("bad len {}", w.len()));
            }
            if w.iter().any(|&v| !(-0.5..=0.5).contains(&v)) {
                return Err("out of range".into());
            }
            Ok(())
        });
    }
}
