//! SplitMix64-based PRNG: deterministic, seedable, fast, and good enough
//! for weight init / synthetic workloads / property tests. (Vigna 2015 —
//! passes BigCrush; NOT cryptographic.)

/// Seeded pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw u64 (SplitMix64 step).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        // top 24 bits → [0, 1) with full f32 mantissa coverage
        (self.next_u64() >> 40) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard-normal-ish sample (Irwin–Hall of 12 uniforms): mean 0,
    /// variance 1 — plenty for weight init and test data.
    pub fn normal(&mut self) -> f32 {
        (0..12).map(|_| self.f32()).sum::<f32>() - 6.0
    }

    /// Vector of uniform values in [lo, hi).
    pub fn vec_range(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range(lo, hi)).collect()
    }

    /// Vector of normal samples.
    pub fn vec_normal(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::seed_from_u64(1).next_u64(), Rng::seed_from_u64(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn mean_and_variance_sane() {
        let mut r = Rng::seed_from_u64(5);
        let v = r.vec_normal(20_000);
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..1000 {
            assert!(r.below(3) < 3);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
