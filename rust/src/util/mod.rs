//! In-tree utilities replacing crates the offline vendor set lacks:
//! a seeded PRNG (`rand`), scratch directories (`tempfile`), a micro
//! benchmark harness (`criterion`), and a property-testing loop
//! (`proptest`). Small by design; each piece covers exactly what this
//! repo needs and is tested here.

mod bench;
mod proptest;
mod rng;
mod tempdir;

pub use bench::{
    baseline_ns, bench, header as bench_header, json_field_f64, smoke as bench_smoke, BenchResult,
    JsonReport,
};
pub use proptest::{forall, Gen};
pub use rng::Rng;
pub use tempdir::TempDir;
