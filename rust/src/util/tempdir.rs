//! Scratch directory with drop-cleanup (stand-in for the `tempfile`
//! crate). Unique per process + counter, rooted under the system temp dir.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory removed (recursively) on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh scratch directory.
    pub fn new() -> std::io::Result<Self> {
        let id = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "subaccel-{}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0),
            id
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Path of a file inside the directory.
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_write_cleanup() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.file("x.txt"), b"hello").unwrap();
            assert!(d.file("x.txt").exists());
        }
        assert!(!p.exists(), "tempdir not cleaned up");
    }

    #[test]
    fn dirs_are_unique() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
