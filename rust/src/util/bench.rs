//! Micro-benchmark harness (stand-in for `criterion`): warmup, repeated
//! timed runs, mean/median/min/stddev, readable one-line report. Used by
//! every target in `benches/`.

use std::time::{Duration, Instant};

/// Statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} ±{:>10}  ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.min),
            fmt_dur(self.stddev),
            self.iters
        )
    }

    /// Throughput in items/sec given items processed per iteration.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.mean.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Smoke mode: `SUBACCEL_BENCH_SMOKE=1` (set by `scripts/check.sh
/// --smoke`) clamps every [`bench`] call to zero warmup and a single
/// timed iteration, so each bench target exercises its full code path in
/// seconds. Numbers printed under smoke mode are *not* measurements.
pub fn smoke() -> bool {
    std::env::var_os("SUBACCEL_BENCH_SMOKE").is_some()
}

/// Run `f` with warmup, then time `iters` runs. `f` should return
/// something cheap (e.g. a checksum) to inhibit dead-code elimination;
/// the value is passed through `std::hint::black_box` anyway. Under
/// [`smoke`] mode the warmup/iteration counts are clamped to `(0, 1)`.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    let (warmup, iters) = if smoke() { (0, 1) } else { (warmup, iters) };
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let mean = total / iters as u32;
    let median = samples[iters / 2];
    let min = samples[0];
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|s| (s.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        median,
        min,
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

/// Column header matching [`BenchResult::report`].
pub fn header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>12} {:>11}",
        "benchmark", "mean", "median", "min", "stddev"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || {
            n += 1;
            n
        });
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + timed
        assert!(r.min <= r.median && r.median <= r.mean + r.stddev * 10);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn throughput() {
        let r = bench("sleepless", 0, 3, || std::thread::sleep(Duration::from_millis(1)));
        let t = r.throughput(100);
        assert!(t > 10.0 && t < 100_000.0, "{t}");
    }
}
