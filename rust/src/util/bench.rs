//! Micro-benchmark harness (stand-in for `criterion`): warmup, repeated
//! timed runs, mean/median/min/stddev, readable one-line report. Used by
//! every target in `benches/`.

use std::time::{Duration, Instant};

/// Statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} ±{:>10}  ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.min),
            fmt_dur(self.stddev),
            self.iters
        )
    }

    /// Throughput in items/sec given items processed per iteration.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.mean.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Smoke mode: `SUBACCEL_BENCH_SMOKE=1` (set by `scripts/check.sh
/// --smoke`) clamps every [`bench`] call to zero warmup and a single
/// timed iteration, so each bench target exercises its full code path in
/// seconds. Numbers printed under smoke mode are *not* measurements.
pub fn smoke() -> bool {
    std::env::var_os("SUBACCEL_BENCH_SMOKE").is_some()
}

/// Run `f` with warmup, then time `iters` runs. `f` should return
/// something cheap (e.g. a checksum) to inhibit dead-code elimination;
/// the value is passed through `std::hint::black_box` anyway. Under
/// [`smoke`] mode the warmup/iteration counts are clamped to `(0, 1)`.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    let (warmup, iters) = if smoke() { (0, 1) } else { (warmup, iters) };
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let mean = total / iters as u32;
    let median = samples[iters / 2];
    let min = samples[0];
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|s| (s.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        median,
        min,
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

/// Column header matching [`BenchResult::report`].
pub fn header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>12} {:>11}",
        "benchmark", "mean", "median", "min", "stddev"
    )
}

/// Machine-readable bench trajectory. A bench target builds one
/// [`JsonReport`] (enabled when `SUBACCEL_BENCH_JSON` names an output
/// path), records selected results with numeric metadata (ops/iter,
/// threads, tile rows, …), and writes them as a JSON array at the end.
/// `scripts/check.sh --smoke` wires this up for `conv_hotpath` so every
/// PR leaves a `BENCH_8.json`-style perf data point behind. Each record
/// carries a `smoke` flag: smoke-mode numbers prove shape, not speed.
///
/// Hand-rolled serialisation (no serde in the vendored set): flat
/// objects of string `name` + integer/float fields only.
#[derive(Debug, Default)]
pub struct JsonReport {
    path: Option<String>,
    entries: Vec<String>,
}

impl JsonReport {
    /// Enabled iff `SUBACCEL_BENCH_JSON` is set (its value is the output
    /// path); otherwise every method is a no-op — benches call
    /// unconditionally.
    pub fn from_env() -> Self {
        Self { path: std::env::var("SUBACCEL_BENCH_JSON").ok(), entries: Vec::new() }
    }

    /// A report writing to a fixed path regardless of the environment
    /// (tests).
    pub fn to_path(path: impl Into<String>) -> Self {
        Self { path: Some(path.into()), entries: Vec::new() }
    }

    pub fn enabled(&self) -> bool {
        self.path.is_some()
    }

    /// Record one result plus numeric metadata, e.g.
    /// `&[("ops", 1.2e6), ("threads", 4.0), ("tile_rows", 16.0)]`.
    pub fn push(&mut self, r: &BenchResult, meta: &[(&str, f64)]) {
        if self.path.is_none() {
            return;
        }
        let mut e = format!(
            "{{\"name\":\"{}\",\"iters\":{},\"ns_per_iter\":{},\"median_ns\":{},\"min_ns\":{},\"stddev_ns\":{},\"smoke\":{}",
            json_escape(&r.name),
            r.iters,
            r.mean.as_nanos(),
            r.median.as_nanos(),
            r.min.as_nanos(),
            r.stddev.as_nanos(),
            smoke(),
        );
        for (key, v) in meta {
            e.push_str(&format!(",\"{}\":{}", json_escape(key), json_f64(*v)));
        }
        e.push('}');
        self.entries.push(e);
    }

    /// Record a standalone metadata entry with no timing attached —
    /// numeric fields plus string fields. This is how non-bench
    /// decisions ride the trajectory: the plan-warm autotuner persists
    /// each layer's chosen row tile as an
    /// `{"name":"autotune:<plan>:<layer>","tile_rows":…,"source":"…"}`
    /// entry that a later run can warm-start from
    /// ([`crate::accel::autotune::TileCache`]).
    pub fn push_fields(&mut self, name: &str, nums: &[(&str, f64)], strs: &[(&str, &str)]) {
        if self.path.is_none() {
            return;
        }
        let mut e = format!("{{\"name\":\"{}\",\"smoke\":{}", json_escape(name), smoke());
        for (key, v) in nums {
            e.push_str(&format!(",\"{}\":{}", json_escape(key), json_f64(*v)));
        }
        for (key, v) in strs {
            e.push_str(&format!(",\"{}\":\"{}\"", json_escape(key), json_escape(v)));
        }
        e.push('}');
        self.entries.push(e);
    }

    /// Write the collected records as a JSON array; returns the path
    /// written, or `None` when disabled.
    pub fn finish(&self) -> std::io::Result<Option<&str>> {
        match &self.path {
            None => Ok(None),
            Some(p) => {
                let body = format!("[\n  {}\n]\n", self.entries.join(",\n  "));
                std::fs::write(p, body)?;
                Ok(Some(p.as_str()))
            }
        }
    }
}

/// Extract one numeric field from a single flat [`JsonReport`] entry
/// (the reports are written one object per line, so callers scan lines).
/// Only handles the report's own output shape — bare numbers, no nesting.
pub fn json_field_f64(entry: &str, key: &str) -> Option<f64> {
    let k = format!("\"{key}\":");
    let i = entry.find(&k)? + k.len();
    let rest = &entry[i..];
    let end = rest.find(|c| c == ',' || c == '}').unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

/// Look up the entry named `name` in a trajectory file written by
/// [`JsonReport::finish`] and return `(ns_per_iter, smoke)` — the
/// regression gate in `benches/conv_hotpath.rs` compares a fresh run
/// against the recorded baseline with this (skipping smoke-mode
/// baselines, whose single-iteration numbers prove shape, not speed).
pub fn baseline_ns(path: &str, name: &str) -> Option<(f64, bool)> {
    let body = std::fs::read_to_string(path).ok()?;
    let needle = format!("\"name\":\"{}\"", json_escape(name));
    for line in body.lines() {
        if line.contains(&needle) {
            let ns = json_field_f64(line, "ns_per_iter")?;
            return Some((ns, line.contains("\"smoke\":true")));
        }
    }
    None
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn json_f64(v: f64) -> String {
    if !v.is_finite() {
        "null".to_string()
    } else if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || {
            n += 1;
            n
        });
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + timed
        assert!(r.min <= r.median && r.median <= r.mean + r.stddev * 10);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn throughput() {
        let r = bench("sleepless", 0, 3, || std::thread::sleep(Duration::from_millis(1)));
        let t = r.throughput(100);
        assert!(t > 10.0 && t < 100_000.0, "{t}");
    }

    #[test]
    fn json_report_round_trips() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("bench.json");
        let mut rep = JsonReport::to_path(path.to_string_lossy());
        assert!(rep.enabled());
        let r = bench("json \"quoted\" name", 0, 2, || 1u32);
        rep.push(&r, &[("ops", 12.0), ("threads", 1.0), ("tile_rows", 0.5)]);
        let written = rep.finish().unwrap().expect("enabled report writes").to_string();
        let body = std::fs::read_to_string(&written).unwrap();
        assert!(body.trim_start().starts_with('['), "{body}");
        assert!(body.trim_end().ends_with(']'), "{body}");
        assert!(body.contains("\"ns_per_iter\":"), "{body}");
        assert!(body.contains("\\\"quoted\\\""), "escaping: {body}");
        assert!(body.contains("\"ops\":12,"), "{body}");
        assert!(body.contains("\"tile_rows\":0.5"), "{body}");
    }

    #[test]
    fn disabled_json_report_is_a_noop() {
        let mut rep = JsonReport::default();
        assert!(!rep.enabled());
        let r = bench("noop", 0, 1, || 0u32);
        rep.push(&r, &[("ops", 1.0)]);
        assert_eq!(rep.finish().unwrap(), None);
    }

    #[test]
    fn push_fields_and_baseline_lookup_round_trip() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("traj.json");
        let p = path.to_string_lossy().to_string();
        let mut rep = JsonReport::to_path(&p);
        let r = bench("alexconv2 steal", 0, 2, || 1u32);
        rep.push(&r, &[("threads", 4.0)]);
        rep.push_fields(
            "autotune:lenet5:c1",
            &[("tile_rows", 16.0), ("score", 1234.5)],
            &[("source", "autotuned")],
        );
        rep.finish().unwrap();
        // the timed entry is found by exact name with its smoke flag
        let (ns, smoked) = baseline_ns(&p, "alexconv2 steal").expect("entry present");
        assert!(ns >= 0.0);
        assert_eq!(smoked, smoke());
        // prefix names don't alias ("alexconv2 steal" != "alexconv2")
        assert_eq!(baseline_ns(&p, "alexconv2"), None);
        // the fields-only entry carries its numbers and strings
        let body = std::fs::read_to_string(&p).unwrap();
        let line = body
            .lines()
            .find(|l| l.contains("\"name\":\"autotune:lenet5:c1\""))
            .expect("autotune entry present");
        assert_eq!(json_field_f64(line, "tile_rows"), Some(16.0));
        assert_eq!(json_field_f64(line, "score"), Some(1234.5));
        assert!(line.contains("\"source\":\"autotuned\""), "{line}");
        // absent keys and absent files are None, not panics
        assert_eq!(json_field_f64(line, "nope"), None);
        assert_eq!(baseline_ns("/nonexistent/path.json", "x"), None);
    }

    #[test]
    fn json_f64_forms() {
        assert_eq!(json_f64(12.0), "12");
        assert_eq!(json_f64(0.5), "0.5");
        assert_eq!(json_f64(f64::NAN), "null");
    }
}
