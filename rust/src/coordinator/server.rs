//! The coordinator proper: request intake, dynamic batching, the executor
//! actor thread, variant management, and metrics.
//!
//! Built on std threads + channels (the offline vendor set has no async
//! runtime): a bounded `sync_channel` provides backpressure at intake, a
//! batcher thread implements the size-or-deadline policy, and the PJRT
//! executor (not `Send`) lives on its own actor thread.

use super::batcher::{BatchPlan, Batcher};
use crate::data::load_weights;
use crate::metrics::ServerMetrics;
use crate::runtime::{LeNet5Executor, Runtime, Variant};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory holding `*.hlo.txt` + `weights.bin`.
    pub artifacts_dir: PathBuf,
    /// Which artifact family to execute.
    pub variant: Variant,
    /// Compiled batch size (an artifact must exist for it: 1, 8 or 32).
    pub batch_size: usize,
    /// Max time a request waits for batch-mates.
    pub max_wait: Duration,
    /// Bound on queued requests before rejection (backpressure).
    pub queue_cap: usize,
    /// Initial rounding size (0 = original weights).
    pub rounding: f32,
    /// Replicated executor workers (each owns a PJRT client + compiled
    /// artifact and pulls batches from a shared queue). >1 pays off on
    /// multi-core hosts; on this 1-core testbed it validates the
    /// architecture, not throughput.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            variant: Variant::XlaNative,
            batch_size: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
            rounding: 0.0,
            workers: 1,
        }
    }
}

/// Receiver side of a pending classification.
pub type LogitsRx = mpsc::Receiver<Result<Vec<f32>>>;

/// One classification request travelling through the pipeline.
struct Request {
    image: Tensor,
    submitted: Instant,
    reply: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// A batch travelling from the batcher to whichever worker grabs it.
struct WorkBatch {
    images: Tensor,
    replies: Vec<Request>,
}

/// Per-worker control messages (broadcast by the coordinator).
enum Ctl {
    SetRounding { rounding: f32, reply: mpsc::SyncSender<Result<usize>> },
}

/// Handle to a running coordinator. Clone-free; share via `Arc`.
pub struct Coordinator {
    tx: mpsc::SyncSender<Request>,
    ctls: Vec<mpsc::Sender<Ctl>>,
    metrics: Arc<ServerMetrics>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the pipeline: executor actor thread + batcher thread.
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        let metrics = Arc::new(ServerMetrics::new());
        let n_workers = cfg.workers.max(1);
        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(cfg.queue_cap);
        let (work_tx, work_rx) = mpsc::channel::<WorkBatch>();
        let shared_rx = Arc::new(std::sync::Mutex::new(work_rx));

        // --- executor workers: each owns its (non-Send) PJRT state -------
        let mut workers = Vec::with_capacity(n_workers);
        let mut ctls = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (init_tx, init_rx) = mpsc::sync_channel::<Result<()>>(1);
            let (ctl_tx, ctl_rx) = mpsc::channel::<Ctl>();
            let wcfg = cfg.clone();
            let wmetrics = metrics.clone();
            let wshared = shared_rx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pjrt-executor-{w}"))
                .spawn(move || worker_loop(wcfg, wshared, ctl_rx, init_tx, wmetrics))
                .context("spawn executor thread")?;
            init_rx
                .recv()
                .map_err(|_| anyhow!("executor thread {w} died during init"))??;
            workers.push(handle);
            ctls.push(ctl_tx);
        }

        // --- batcher thread ----------------------------------------------
        let policy = Batcher::new(cfg.batch_size, cfg.max_wait);
        let bmetrics = metrics.clone();
        let batch_size = cfg.batch_size;
        let batcher = std::thread::Builder::new()
            .name("batcher".into())
            .spawn(move || batcher_loop(policy, batch_size, req_rx, work_tx, bmetrics))
            .context("spawn batcher thread")?;

        Ok(Self { tx: req_tx, ctls, metrics, batcher: Some(batcher), workers })
    }

    /// Submit one `(1, 1, 32, 32)` image; returns a receiver that resolves
    /// to 10 logits. Fails fast when the queue is full (backpressure).
    pub fn submit(&self, image: Tensor) -> Result<LogitsRx> {
        if image.shape() != [1, 1, 32, 32] {
            bail!("expected (1,1,32,32) input, got {:?}", image.shape());
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::sync_channel(1);
        let req = Request { image, submitted: Instant::now(), reply };
        if self.tx.try_send(req).is_err() {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            bail!("queue full: backpressure rejection");
        }
        Ok(rx)
    }

    /// Blocking classify convenience.
    pub fn classify(&self, image: Tensor) -> Result<Vec<f32>> {
        self.submit(image)?
            .recv()
            .map_err(|_| anyhow!("pipeline dropped request"))?
    }

    /// Install the rounding variant (preprocess + swap weight literals) on
    /// every worker. Returns the number of combined pairs. The variant is
    /// fully installed on all replicas before this returns — later
    /// requests are guaranteed the new weights.
    pub fn set_rounding(&self, rounding: f32) -> Result<usize> {
        let mut rxs = Vec::with_capacity(self.ctls.len());
        for ctl in &self.ctls {
            let (reply, rx) = mpsc::sync_channel(1);
            ctl.send(Ctl::SetRounding { rounding, reply })
                .map_err(|_| anyhow!("executor thread gone"))?;
            rxs.push(rx);
        }
        let mut pairs = 0;
        for rx in rxs {
            pairs = rx.recv().map_err(|_| anyhow!("executor thread dropped reply"))??;
        }
        Ok(pairs)
    }

    pub fn metrics(&self) -> Arc<ServerMetrics> {
        self.metrics.clone()
    }

    /// Stop intake, drain, and join both threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // 1. close intake: swap our request sender for a dead one so the
        //    batcher's recv() disconnects and it drains pending work
        if let Some(h) = self.batcher.take() {
            let (dead_tx, _) = mpsc::sync_channel(1);
            let old = std::mem::replace(&mut self.tx, dead_tx);
            drop(old);
            let _ = h.join();
        }
        // 2. the batcher exiting dropped the work sender; workers drain
        //    the shared queue, observe the disconnect, and return
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Executor worker: builds the runtime in-thread (PJRT state is !Send),
/// then alternates between its control channel and the shared batch
/// queue until the queue disconnects (shutdown).
fn worker_loop(
    cfg: ServeConfig,
    shared: Arc<std::sync::Mutex<mpsc::Receiver<WorkBatch>>>,
    ctl_rx: mpsc::Receiver<Ctl>,
    init_tx: mpsc::SyncSender<Result<()>>,
    metrics: Arc<ServerMetrics>,
) {
    type Built = (LeNet5Executor, std::collections::HashMap<String, Tensor>);
    let built = (|| -> Result<Built> {
        let rt = Runtime::cpu()?;
        let base = load_weights(cfg.artifacts_dir.join("weights.bin"))?;
        let mut exe =
            LeNet5Executor::load(&rt, &cfg.artifacts_dir, cfg.variant, cfg.batch_size, &base)?;
        if cfg.rounding > 0.0 {
            exe.install_variant(&base, cfg.rounding)?;
        }
        Ok((exe, base))
    })();
    let (mut exe, base) = match built {
        Ok(v) => {
            let _ = init_tx.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };

    loop {
        // control first: variant switches take effect before the next batch
        while let Ok(Ctl::SetRounding { rounding, reply }) = ctl_rx.try_recv() {
            let _ = reply.send(exe.install_variant(&base, rounding));
        }
        // pull one batch from the shared queue (short timeout so control
        // messages stay responsive)
        let msg = {
            let guard = shared.lock().expect("work queue poisoned");
            guard.recv_timeout(Duration::from_millis(5))
        };
        let WorkBatch { images, replies } = match msg {
            Ok(b) => b,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        let t0 = Instant::now();
        let result = exe.execute(&images);
        metrics.execute_latency.record(t0.elapsed());
        match result {
            Ok(logits) => {
                let n_classes = logits.shape()[1];
                let data = logits.data();
                for (i, req) in replies.into_iter().enumerate() {
                    let row = data[i * n_classes..(i + 1) * n_classes].to_vec();
                    metrics.e2e_latency.record(req.submitted.elapsed());
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(Ok(row));
                }
            }
            Err(e) => {
                let msg = format!("execute failed: {e:#}");
                for req in replies {
                    let _ = req.reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
    }
}

/// Batcher thread: size-or-deadline grouping, zero-padding partial batches
/// to the compiled batch size. Exits when the request channel closes.
fn batcher_loop(
    policy: Batcher,
    batch_size: usize,
    rx: mpsc::Receiver<Request>,
    work_tx: mpsc::Sender<WorkBatch>,
    metrics: Arc<ServerMetrics>,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(batch_size);
    let mut closed = false;
    while !(closed && pending.is_empty()) {
        if !closed {
            let deadline = policy.deadline(pending.first().map(|r| r.submitted));
            match deadline {
                None => match rx.recv() {
                    Ok(req) => pending.push(req),
                    Err(_) => closed = true,
                },
                Some(d) => {
                    let now = Instant::now();
                    let wait = d.saturating_duration_since(now);
                    match rx.recv_timeout(wait) {
                        Ok(req) => pending.push(req),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
                    }
                }
            }
        }

        let now = Instant::now();
        let oldest = pending.first().map(|r| r.submitted);
        let flush = match policy.decide(pending.len(), oldest, now) {
            BatchPlan::Flush => true,
            BatchPlan::Wait => closed && !pending.is_empty(), // drain on shutdown
        };
        if !flush {
            continue;
        }

        let take = pending.len().min(batch_size);
        let batch: Vec<Request> = pending.drain(..take).collect();
        let mut data = Vec::with_capacity(batch_size * 32 * 32);
        for r in &batch {
            metrics.queue_latency.record(r.submitted.elapsed());
            data.extend_from_slice(r.image.data());
        }
        data.resize(batch_size * 32 * 32, 0.0); // zero-pad to compiled size
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_items.fetch_add(batch.len() as u64, Ordering::Relaxed);
        let images = Tensor::new(&[batch_size, 1, 32, 32], data);
        if work_tx.send(WorkBatch { images, replies: batch }).is_err() {
            return; // executors gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.batch_size, 8);
        assert!(c.queue_cap >= c.batch_size);
    }

    // Full pipeline tests (require artifacts) live in rust/tests/.
}
