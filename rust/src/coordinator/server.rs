//! The coordinator proper: request intake, dynamic batching, the executor
//! worker threads, variant management, and metrics.
//!
//! Built on std threads + channels (the offline vendor set has no async
//! runtime): a bounded `sync_channel` provides backpressure at intake, a
//! batcher thread implements the size-or-deadline policy, and each
//! executor lives on its own worker thread (the PJRT client is not
//! `Send`; the CPU engine keeps its worker pool per replica).
//!
//! Configuration goes through [`ServeConfig::builder`], which validates
//! combinations (batch size vs compiled artifacts, queue capacity vs
//! batch size, worker counts) at construction — not deep inside
//! [`Coordinator::start`]. Intake errors are typed
//! ([`crate::error::SubaccelError`]) so callers can distinguish
//! `QueueFull` backpressure from `BadShape` rejections.

use super::batcher::{BatchPlan, Batcher};
use crate::accel::{AutotuneBudget, ConvEngine};
use crate::data::load_weights;
use crate::error::SubaccelError;
use crate::metrics::ServerMetrics;
use crate::runtime::{LeNet5Executor, PairedCpuLeNet5, Runtime, Variant};
use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which executor each replica runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// A compiled PJRT artifact family (requires `*.hlo.txt` files
    /// lowered for the configured batch size).
    Pjrt(Variant),
    /// The in-process paired CPU engine ([`PairedCpuLeNet5`]): no
    /// artifact needed, any batch size, `engine_threads` cores per
    /// replica.
    CpuEngine,
}

/// Batch sizes the AOT pipeline lowers artifacts for.
const COMPILED_BATCH_SIZES: [usize; 3] = [1, 8, 32];

/// Coordinator configuration. Construct via [`ServeConfig::builder`];
/// fields are validated together at `build()` time.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    artifacts_dir: PathBuf,
    backend: Backend,
    batch_size: usize,
    max_wait: Duration,
    queue_cap: usize,
    rounding: f32,
    workers: usize,
    engine_threads: usize,
    autotune: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            backend: Backend::Pjrt(Variant::XlaNative),
            batch_size: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
            rounding: 0.0,
            workers: 1,
            engine_threads: 1,
            autotune: true,
        }
    }
}

impl ServeConfig {
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: Self::default() }
    }

    /// Directory holding `*.hlo.txt` + `weights.bin`.
    pub fn artifacts_dir(&self) -> &PathBuf {
        &self.artifacts_dir
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Batch size requests are grouped (and padded) to.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Max time a request waits for batch-mates.
    pub fn max_wait(&self) -> Duration {
        self.max_wait
    }

    /// Bound on queued requests before rejection (backpressure).
    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Initial rounding size (0 = original weights).
    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    /// Replicated executor workers pulling batches from a shared queue.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Engine threads per replica (CPU backend only).
    pub fn engine_threads(&self) -> usize {
        self.engine_threads
    }

    /// Run the plan-warm row-tile sweep while pre-warming CPU replicas
    /// (CPU backend only; the default). Off = static heuristic tiles.
    pub fn autotune(&self) -> bool {
        self.autotune
    }
}

/// Validating builder for [`ServeConfig`] — invalid combinations are
/// rejected here, with a typed [`SubaccelError::InvalidConfig`] naming
/// the offending field.
#[derive(Debug, Clone)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.cfg.backend = backend;
        self
    }

    /// Shorthand for `backend(Backend::Pjrt(variant))`.
    pub fn variant(self, variant: Variant) -> Self {
        self.backend(Backend::Pjrt(variant))
    }

    pub fn batch_size(mut self, n: usize) -> Self {
        self.cfg.batch_size = n;
        self
    }

    pub fn max_wait(mut self, d: Duration) -> Self {
        self.cfg.max_wait = d;
        self
    }

    pub fn queue_cap(mut self, n: usize) -> Self {
        self.cfg.queue_cap = n;
        self
    }

    pub fn rounding(mut self, r: f32) -> Self {
        self.cfg.rounding = r;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    pub fn engine_threads(mut self, n: usize) -> Self {
        self.cfg.engine_threads = n;
        self
    }

    pub fn autotune(mut self, on: bool) -> Self {
        self.cfg.autotune = on;
        self
    }

    pub fn build(self) -> Result<ServeConfig, SubaccelError> {
        let c = &self.cfg;
        let invalid = |field: &'static str, reason: String| {
            Err(SubaccelError::InvalidConfig { field, reason })
        };
        if c.workers == 0 {
            return invalid("workers", "at least one executor worker is required".into());
        }
        if c.engine_threads == 0 {
            return invalid("engine_threads", "engine needs at least one thread".into());
        }
        if c.batch_size == 0 {
            return invalid("batch_size", "batch size must be at least 1".into());
        }
        if c.queue_cap < c.batch_size {
            return invalid(
                "queue_cap",
                format!(
                    "queue capacity {} cannot hold one batch of {}",
                    c.queue_cap, c.batch_size
                ),
            );
        }
        if !c.rounding.is_finite() || c.rounding < 0.0 {
            return invalid("rounding", format!("rounding must be finite and ≥ 0, got {}", c.rounding));
        }
        if matches!(c.backend, Backend::Pjrt(_))
            && !COMPILED_BATCH_SIZES.contains(&c.batch_size)
        {
            return invalid(
                "batch_size",
                format!(
                    "no compiled artifact for batch {} (available: {:?}); \
                     use Backend::CpuEngine for arbitrary batch sizes",
                    c.batch_size, COMPILED_BATCH_SIZES
                ),
            );
        }
        Ok(self.cfg)
    }
}

/// Receiver side of a pending classification.
pub type LogitsRx = mpsc::Receiver<Result<Vec<f32>>>;

/// One classification request travelling through the pipeline.
struct Request {
    image: Tensor,
    submitted: Instant,
    reply: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// A batch travelling from the batcher to whichever worker grabs it.
struct WorkBatch {
    images: Tensor,
    replies: Vec<Request>,
}

/// Per-worker control messages (broadcast by the coordinator).
enum Ctl {
    SetRounding { rounding: f32, reply: mpsc::SyncSender<Result<usize>> },
}

/// Handle to a running coordinator. Clone-free; share via `Arc`.
pub struct Coordinator {
    tx: mpsc::SyncSender<Request>,
    ctls: Vec<mpsc::Sender<Ctl>>,
    metrics: Arc<ServerMetrics>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the pipeline: executor worker threads + batcher thread.
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        let metrics = Arc::new(ServerMetrics::new());
        let n_workers = cfg.workers.max(1);
        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(cfg.queue_cap);
        let (work_tx, work_rx) = mpsc::channel::<WorkBatch>();
        let shared_rx = Arc::new(std::sync::Mutex::new(work_rx));

        // --- executor workers: each owns its backend state ---------------
        let mut workers = Vec::with_capacity(n_workers);
        let mut ctls = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (init_tx, init_rx) = mpsc::sync_channel::<Result<()>>(1);
            let (ctl_tx, ctl_rx) = mpsc::channel::<Ctl>();
            let wcfg = cfg.clone();
            let wmetrics = metrics.clone();
            let wshared = shared_rx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("executor-{w}"))
                .spawn(move || worker_loop(wcfg, wshared, ctl_rx, init_tx, wmetrics))
                .context("spawn executor thread")?;
            init_rx
                .recv()
                .map_err(|_| anyhow!("executor thread {w} died during init"))??;
            workers.push(handle);
            ctls.push(ctl_tx);
        }

        // --- batcher thread ----------------------------------------------
        let policy = Batcher::new(cfg.batch_size, cfg.max_wait);
        let bmetrics = metrics.clone();
        // PJRT artifacts are compiled for one fixed batch shape, so every
        // partial batch zero-pads to it; the CPU engine is shape-flexible
        // and takes partial batches at the nearest pre-warmed padded size
        let flexible = matches!(cfg.backend, Backend::CpuEngine);
        let batcher = std::thread::Builder::new()
            .name("batcher".into())
            .spawn(move || batcher_loop(policy, flexible, req_rx, work_tx, bmetrics))
            .context("spawn batcher thread")?;

        Ok(Self { tx: req_tx, ctls, metrics, batcher: Some(batcher), workers })
    }

    /// Submit one `(1, 1, 32, 32)` image; returns a receiver that resolves
    /// to 10 logits. Errors are typed: [`SubaccelError::BadShape`] for a
    /// wrong input, [`SubaccelError::QueueFull`] when backpressure kicks
    /// in (retriable), [`SubaccelError::PipelineClosed`] after shutdown.
    pub fn submit(&self, image: Tensor) -> Result<LogitsRx, SubaccelError> {
        if image.shape() != [1, 1, 32, 32] {
            return Err(SubaccelError::BadShape {
                expected: vec![1, 1, 32, 32],
                got: image.shape().to_vec(),
            });
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::sync_channel(1);
        let req = Request { image, submitted: Instant::now(), reply };
        match self.tx.try_send(req) {
            Ok(()) => Ok(rx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubaccelError::QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubaccelError::PipelineClosed)
            }
        }
    }

    /// Blocking classify convenience (`anyhow` at this edge; downcast to
    /// [`SubaccelError`] to branch on intake failures).
    pub fn classify(&self, image: Tensor) -> Result<Vec<f32>> {
        self.submit(image)?
            .recv()
            .map_err(|_| anyhow!("pipeline dropped request"))?
    }

    /// Install the rounding variant (preprocess + swap weights) on every
    /// worker. Returns the number of combined pairs. The variant is fully
    /// installed on all replicas before this returns — later requests are
    /// guaranteed the new weights.
    pub fn set_rounding(&self, rounding: f32) -> Result<usize> {
        let mut rxs = Vec::with_capacity(self.ctls.len());
        for ctl in &self.ctls {
            let (reply, rx) = mpsc::sync_channel(1);
            ctl.send(Ctl::SetRounding { rounding, reply })
                .map_err(|_| anyhow!("executor thread gone"))?;
            rxs.push(rx);
        }
        let mut pairs = 0;
        for rx in rxs {
            pairs = rx.recv().map_err(|_| anyhow!("executor thread dropped reply"))??;
        }
        Ok(pairs)
    }

    pub fn metrics(&self) -> Arc<ServerMetrics> {
        self.metrics.clone()
    }

    /// Stop intake, drain, and join both threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // 1. close intake: swap our request sender for a dead one so the
        //    batcher's recv() disconnects and it drains pending work
        if let Some(h) = self.batcher.take() {
            let (dead_tx, _) = mpsc::sync_channel(1);
            let old = std::mem::replace(&mut self.tx, dead_tx);
            drop(old);
            let _ = h.join();
        }
        // 2. the batcher exiting dropped the work sender; workers drain
        //    the shared queue, observe the disconnect, and return
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// A replica's executor: either a compiled PJRT artifact or the paired
/// CPU engine. Same execute/variant-switch contract either way.
enum WorkerExec {
    Pjrt(LeNet5Executor),
    Cpu(PairedCpuLeNet5),
}

impl WorkerExec {
    fn execute(&mut self, images: &Tensor) -> Result<Tensor> {
        match self {
            WorkerExec::Pjrt(e) => e.execute(images),
            WorkerExec::Cpu(e) => e.execute(images),
        }
    }

    fn install_variant(
        &mut self,
        base: &HashMap<String, Tensor>,
        rounding: f32,
    ) -> Result<usize> {
        match self {
            WorkerExec::Pjrt(e) => e.install_variant(base, rounding),
            WorkerExec::Cpu(e) => e.install(base, rounding),
        }
    }
}

/// Executor worker: builds its backend in-thread (PJRT state is !Send;
/// the CPU engine's worker pool belongs to this replica), then alternates
/// between its control channel and the shared batch queue until the
/// queue disconnects (shutdown).
fn worker_loop(
    cfg: ServeConfig,
    shared: Arc<std::sync::Mutex<mpsc::Receiver<WorkBatch>>>,
    ctl_rx: mpsc::Receiver<Ctl>,
    init_tx: mpsc::SyncSender<Result<()>>,
    metrics: Arc<ServerMetrics>,
) {
    type Built = (WorkerExec, HashMap<String, Tensor>);
    let built = (|| -> Result<Built> {
        let base = load_weights(cfg.artifacts_dir.join("weights.bin"))?;
        let exec = match cfg.backend {
            Backend::Pjrt(variant) => {
                let rt = Runtime::cpu()?;
                let mut exe = LeNet5Executor::load(
                    &rt,
                    &cfg.artifacts_dir,
                    variant,
                    cfg.batch_size,
                    &base,
                )?;
                if cfg.rounding > 0.0 {
                    exe.install_variant(&base, cfg.rounding)?;
                }
                WorkerExec::Pjrt(exe)
            }
            Backend::CpuEngine => {
                let engine = Arc::new(ConvEngine::new(cfg.engine_threads)?);
                let mut cpu = PairedCpuLeNet5::new(engine, &base, cfg.rounding)?;
                // pre-warm one plan per padded size the batcher can emit
                // under low load (powers of two up to the configured
                // batch), so even deadline-flushed partial batches run
                // allocation-free from the first request; with autotune on
                // (default) the warm also sweeps row tiles per conv layer
                // — deterministic cost-model mode, so every replica lands
                // on the same tiles
                for b in Batcher::new(cfg.batch_size, cfg.max_wait).padded_sizes() {
                    if cfg.autotune {
                        cpu.warm_autotuned(b, &AutotuneBudget::default(), None)?;
                    } else {
                        cpu.warm(b)?;
                    }
                }
                WorkerExec::Cpu(cpu)
            }
        };
        Ok((exec, base))
    })();
    let (mut exe, base) = match built {
        Ok(v) => {
            let _ = init_tx.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };

    loop {
        // control first: variant switches take effect before the next batch
        while let Ok(Ctl::SetRounding { rounding, reply }) = ctl_rx.try_recv() {
            let _ = reply.send(exe.install_variant(&base, rounding));
        }
        // pull one batch from the shared queue (short timeout so control
        // messages stay responsive)
        let msg = {
            // The mutex only guards a Receiver handle — nothing about it
            // is invalidated by another worker panicking mid-recv, so a
            // poisoned lock is recovered rather than cascading the panic
            // into every surviving replica.
            let guard = shared.lock().unwrap_or_else(|e| e.into_inner());
            guard.recv_timeout(Duration::from_millis(5))
        };
        let WorkBatch { images, replies } = match msg {
            Ok(b) => b,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        let t0 = Instant::now();
        let result = exe.execute(&images);
        metrics.execute_latency.record(t0.elapsed());
        match result {
            Ok(logits) => {
                let n_classes = logits.shape()[1];
                let data = logits.data();
                for (i, req) in replies.into_iter().enumerate() {
                    let row = data[i * n_classes..(i + 1) * n_classes].to_vec();
                    metrics.e2e_latency.record(req.submitted.elapsed());
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(Ok(row));
                }
            }
            Err(e) => {
                let msg = format!("execute failed: {e:#}");
                for req in replies {
                    let _ = req.reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
    }
}

/// Batcher thread: size-or-deadline grouping. Partial batches zero-pad
/// to the compiled batch size on fixed-shape backends, or to the
/// smallest pre-warmed [`Batcher::padded_size`] on shape-flexible ones.
/// Exits when the request channel closes.
fn batcher_loop(
    policy: Batcher,
    flexible: bool,
    rx: mpsc::Receiver<Request>,
    work_tx: mpsc::Sender<WorkBatch>,
    metrics: Arc<ServerMetrics>,
) {
    let batch_size = policy.max_batch;
    let mut pending: Vec<Request> = Vec::with_capacity(batch_size);
    let mut closed = false;
    while !(closed && pending.is_empty()) {
        if !closed {
            let deadline = policy.deadline(pending.first().map(|r| r.submitted));
            match deadline {
                None => match rx.recv() {
                    Ok(req) => pending.push(req),
                    Err(_) => closed = true,
                },
                Some(d) => {
                    let now = Instant::now();
                    let wait = d.saturating_duration_since(now);
                    match rx.recv_timeout(wait) {
                        Ok(req) => pending.push(req),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
                    }
                }
            }
        }

        let now = Instant::now();
        let oldest = pending.first().map(|r| r.submitted);
        let flush = match policy.decide(pending.len(), oldest, now) {
            BatchPlan::Flush => true,
            BatchPlan::Wait => closed && !pending.is_empty(), // drain on shutdown
        };
        if !flush {
            continue;
        }

        let take = pending.len().min(batch_size);
        let batch: Vec<Request> = pending.drain(..take).collect();
        // fixed-shape backends (PJRT) always pad to the compiled batch;
        // shape-flexible ones take the smallest pre-warmed padded size
        // that holds the batch, so low-load partials run ~batch-size
        // cheaper instead of paying for a full batch every deadline
        let padded = if flexible { policy.padded_size(batch.len()) } else { batch_size };
        let mut data = Vec::with_capacity(padded * 32 * 32);
        for r in &batch {
            metrics.queue_latency.record(r.submitted.elapsed());
            data.extend_from_slice(r.image.data());
        }
        data.resize(padded * 32 * 32, 0.0); // zero-pad to the batch shape
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_items.fetch_add(batch.len() as u64, Ordering::Relaxed);
        let images = Tensor::new(&[padded, 1, 32, 32], data);
        if work_tx.send(WorkBatch { images, replies: batch }).is_err() {
            return; // executors gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.batch_size(), 8);
        assert!(c.queue_cap() >= c.batch_size());
    }

    #[test]
    fn builder_round_trips_fields() {
        let c = ServeConfig::builder()
            .artifacts_dir("somewhere")
            .backend(Backend::CpuEngine)
            .batch_size(4)
            .max_wait(Duration::from_millis(7))
            .queue_cap(64)
            .rounding(0.25)
            .workers(2)
            .engine_threads(3)
            .autotune(false)
            .build()
            .unwrap();
        assert_eq!(c.artifacts_dir(), &PathBuf::from("somewhere"));
        assert_eq!(c.backend(), Backend::CpuEngine);
        assert_eq!(c.batch_size(), 4);
        assert_eq!(c.max_wait(), Duration::from_millis(7));
        assert_eq!(c.queue_cap(), 64);
        assert_eq!(c.rounding(), 0.25);
        assert_eq!(c.workers(), 2);
        assert_eq!(c.engine_threads(), 3);
        assert!(!c.autotune());
        // autotune defaults on for CPU replicas
        assert!(ServeConfig::default().autotune());
    }

    #[test]
    fn builder_rejects_zero_workers() {
        let err = ServeConfig::builder().workers(0).build().unwrap_err();
        assert!(matches!(err, SubaccelError::InvalidConfig { field: "workers", .. }), "{err}");
    }

    #[test]
    fn builder_rejects_queue_smaller_than_batch() {
        let err = ServeConfig::builder().batch_size(8).queue_cap(2).build().unwrap_err();
        assert!(
            matches!(err, SubaccelError::InvalidConfig { field: "queue_cap", .. }),
            "{err}"
        );
        // equality is allowed
        assert!(ServeConfig::builder().batch_size(8).queue_cap(8).build().is_ok());
    }

    #[test]
    fn builder_rejects_uncompiled_pjrt_batch() {
        let err = ServeConfig::builder().batch_size(7).build().unwrap_err();
        match err {
            SubaccelError::InvalidConfig { field: "batch_size", reason } => {
                assert!(reason.contains("no compiled artifact"), "{reason}");
            }
            other => panic!("expected batch_size rejection, got {other}"),
        }
        // the CPU engine has no compiled shape constraint
        assert!(ServeConfig::builder()
            .backend(Backend::CpuEngine)
            .batch_size(7)
            .build()
            .is_ok());
    }

    #[test]
    fn builder_rejects_bad_rounding_and_zero_threads() {
        assert!(matches!(
            ServeConfig::builder().rounding(f32::NAN).build().unwrap_err(),
            SubaccelError::InvalidConfig { field: "rounding", .. }
        ));
        assert!(matches!(
            ServeConfig::builder().rounding(-0.1).build().unwrap_err(),
            SubaccelError::InvalidConfig { field: "rounding", .. }
        ));
        assert!(matches!(
            ServeConfig::builder().engine_threads(0).build().unwrap_err(),
            SubaccelError::InvalidConfig { field: "engine_threads", .. }
        ));
        assert!(matches!(
            ServeConfig::builder().batch_size(0).build().unwrap_err(),
            SubaccelError::InvalidConfig { field: "batch_size", .. }
        ));
    }

    // Full pipeline tests (require artifacts) live in rust/tests/.
}
