//! Dynamic batching policy, kept pure for unit testing: decide when a
//! pending set of requests should be flushed into an executor batch.

use std::time::{Duration, Instant};

/// Outcome of a batching decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPlan {
    /// Keep waiting (queue not full, deadline not reached).
    Wait,
    /// Flush the current pending requests now.
    Flush,
}

/// Size-or-deadline batching policy.
///
/// A batch is flushed when it reaches `max_batch` items, or when the
/// oldest pending item has waited `max_wait`. An empty queue never
/// flushes.
#[derive(Debug, Clone, Copy)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch > 0, "max_batch must be positive");
        Self { max_batch, max_wait }
    }

    /// Decide given the current queue depth and the arrival time of the
    /// oldest pending request.
    pub fn decide(&self, pending: usize, oldest: Option<Instant>, now: Instant) -> BatchPlan {
        if pending == 0 {
            return BatchPlan::Wait;
        }
        if pending >= self.max_batch {
            return BatchPlan::Flush;
        }
        match oldest {
            Some(t) if now.duration_since(t) >= self.max_wait => BatchPlan::Flush,
            _ => BatchPlan::Wait,
        }
    }

    /// Deadline at which the current oldest request forces a flush.
    pub fn deadline(&self, oldest: Option<Instant>) -> Option<Instant> {
        oldest.map(|t| t + self.max_wait)
    }

    /// The padded batch sizes a shape-flexible backend can see: powers
    /// of two below `max_batch`, plus `max_batch` itself. Deadline
    /// flushes under low load pad a partial batch up to the smallest of
    /// these that holds it ([`Batcher::padded_size`]) instead of always
    /// the full `max_batch` — small bounded set, so a backend can
    /// pre-warm one plan executor per size and stay allocation-free for
    /// every batch the coordinator will ever emit.
    pub fn padded_sizes(&self) -> Vec<usize> {
        let mut sizes = Vec::new();
        let mut s = 1usize;
        while s < self.max_batch {
            sizes.push(s);
            s *= 2;
        }
        sizes.push(self.max_batch);
        sizes
    }

    /// Smallest emitted padded size that holds `n` requests; always one
    /// of [`Batcher::padded_sizes`] (inputs beyond `max_batch` clamp to
    /// `max_batch` — the batcher never drains more than that at once).
    pub fn padded_size(&self, n: usize) -> usize {
        let n = n.min(self.max_batch);
        let mut s = 1usize;
        while s < n {
            s *= 2;
        }
        s.min(self.max_batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b() -> Batcher {
        Batcher::new(4, Duration::from_millis(10))
    }

    #[test]
    fn empty_never_flushes() {
        let now = Instant::now();
        assert_eq!(b().decide(0, None, now), BatchPlan::Wait);
        assert_eq!(b().decide(0, None, now + Duration::from_secs(60)), BatchPlan::Wait);
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let now = Instant::now();
        assert_eq!(b().decide(4, Some(now), now), BatchPlan::Flush);
        assert_eq!(b().decide(9, Some(now), now), BatchPlan::Flush);
    }

    #[test]
    fn deadline_flushes_partial() {
        let t0 = Instant::now();
        let late = t0 + Duration::from_millis(11);
        assert_eq!(b().decide(2, Some(t0), t0), BatchPlan::Wait);
        assert_eq!(b().decide(2, Some(t0), late), BatchPlan::Flush);
    }

    #[test]
    fn deadline_exact_boundary_flushes() {
        let t0 = Instant::now();
        assert_eq!(b().decide(1, Some(t0), t0 + Duration::from_millis(10)), BatchPlan::Flush);
    }

    #[test]
    fn deadline_accessor() {
        let t0 = Instant::now();
        assert_eq!(b().deadline(None), None);
        assert_eq!(b().deadline(Some(t0)), Some(t0 + Duration::from_millis(10)));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_batch_rejected() {
        Batcher::new(0, Duration::ZERO);
    }

    #[test]
    fn overfull_queue_flushes_even_when_fresh() {
        // pending > max_batch must flush regardless of age — even with no
        // oldest timestamp at all (the size test runs before the deadline
        // test, so a missing timestamp cannot delay an overfull queue).
        let now = Instant::now();
        assert_eq!(b().decide(5, Some(now), now), BatchPlan::Flush);
        assert_eq!(b().decide(5, None, now), BatchPlan::Flush);
    }

    #[test]
    fn just_under_deadline_waits() {
        // the boundary is >= max_wait: one nanosecond short still waits
        let t0 = Instant::now();
        let almost = t0 + Duration::from_millis(10) - Duration::from_nanos(1);
        assert_eq!(b().decide(1, Some(t0), almost), BatchPlan::Wait);
    }

    #[test]
    fn padded_sizes_are_powers_of_two_up_to_max() {
        assert_eq!(b().padded_sizes(), vec![1, 2, 4]); // max_batch 4
        assert_eq!(Batcher::new(6, Duration::ZERO).padded_sizes(), vec![1, 2, 4, 6]);
        assert_eq!(Batcher::new(1, Duration::ZERO).padded_sizes(), vec![1]);
        assert_eq!(Batcher::new(32, Duration::ZERO).padded_sizes(), vec![1, 2, 4, 8, 16, 32]);
    }

    #[test]
    fn padded_size_is_smallest_emitted_cover() {
        let six = Batcher::new(6, Duration::ZERO);
        assert_eq!(six.padded_size(1), 1);
        assert_eq!(six.padded_size(2), 2);
        assert_eq!(six.padded_size(3), 4);
        assert_eq!(six.padded_size(5), 6); // 8 > max_batch → clamp
        assert_eq!(six.padded_size(6), 6);
        assert_eq!(six.padded_size(100), 6);
        // every answer is in padded_sizes()
        for n in 1..=12 {
            assert!(six.padded_sizes().contains(&six.padded_size(n)), "n={n}");
        }
    }

    #[test]
    fn zero_wait_flushes_any_nonempty_queue() {
        // max_wait == 0 degenerates to flush-on-arrival, but an empty
        // queue must still wait
        let z = Batcher::new(4, Duration::ZERO);
        let now = Instant::now();
        assert_eq!(z.decide(1, Some(now), now), BatchPlan::Flush);
        assert_eq!(z.decide(0, None, now), BatchPlan::Wait);
    }
}
