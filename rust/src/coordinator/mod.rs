//! L3 serving coordinator: async request router + dynamic batcher in
//! front of the PJRT executor.
//!
//! Architecture (vLLM-router-like, scaled to this workload):
//!
//! ```text
//!  clients ──▶ bounded queue ──▶ batcher task ──▶ worker thread (actor,
//!    classify()     │   (backpressure)  │          owns PJRT executor)
//!    oneshot ◀──────┴──────── replies ◀─┴─────────────┘
//! ```
//!
//! * The batcher groups requests up to the artifact's compiled batch size
//!   or a deadline (`max_wait`), padding partial batches — classic
//!   dynamic batching.
//! * The PJRT client is not `Send`/`Sync`, so the executor lives on one
//!   dedicated worker thread; batches cross via a channel (actor pattern).
//! * Rounding variants are installed by swapping cached weight literals —
//!   the artifact takes weights as arguments, so variant switches never
//!   recompile.

mod batcher;
mod server;

pub use batcher::{BatchPlan, Batcher};
pub use server::{Coordinator, ServeConfig};
