//! L3 serving coordinator: async request router + dynamic batcher in
//! front of the PJRT executor.
//!
//! Architecture (vLLM-router-like, scaled to this workload):
//!
//! ```text
//!  clients ──▶ bounded queue ──▶ batcher task ──▶ worker thread (actor,
//!    classify()     │   (backpressure)  │          owns PJRT executor)
//!    oneshot ◀──────┴──────── replies ◀─┴─────────────┘
//! ```
//!
//! * The batcher groups requests up to the configured batch size or a
//!   deadline (`max_wait`), padding partial batches — classic dynamic
//!   batching.
//! * Each replica owns its executor on a dedicated worker thread. With
//!   [`Backend::Pjrt`] that is a compiled artifact (the PJRT client is
//!   not `Send`/`Sync` — actor pattern); with [`Backend::CpuEngine`] it
//!   is a [`crate::runtime::PairedCpuLeNet5`] running on its own
//!   multi-threaded [`crate::accel::ConvEngine`].
//! * Rounding variants are installed by swapping cached weight literals
//!   (PJRT) or recompiling the packed pairing (CPU) — never recompiling
//!   the artifact.
//! * Configuration is built via the validating
//!   [`ServeConfig::builder`]; intake errors
//!   ([`Coordinator::submit`]) are typed
//!   [`crate::error::SubaccelError`] values.

mod batcher;
mod server;

pub use batcher::{BatchPlan, Batcher};
pub use server::{Backend, Coordinator, LogitsRx, ServeConfig, ServeConfigBuilder};
