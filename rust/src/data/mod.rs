//! Data plane: the STDI tensor-container codec shared with python, plus
//! dataset/golden/weight loading helpers used by examples, tests, and the
//! coordinator.

mod dataset;
mod tensorio;

pub use dataset::{load_dataset, load_golden, load_weights, Dataset, Golden};
pub use tensorio::{load_tensors, save_tensors, TensorData, TensorEntry};
