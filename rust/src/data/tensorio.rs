//! STDI named-tensor container — byte-for-byte mirror of
//! `python/compile/tensorio.py`:
//!
//! ```text
//! magic  b"STDI" | u32 version (=1) | u32 count
//! entry: u16 name_len | name utf-8 | u8 dtype | u8 ndim | u32 dims[ndim] | raw LE
//! dtype: 0 = f32, 1 = i32, 2 = u8
//! ```
//!
//! Round-trip tested here; cross-language compatibility is covered by the
//! integration test that reads python-written artifacts.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"STDI";
const VERSION: u32 = 1;

/// Typed payload of one entry.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorData::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorData::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            TensorData::U8(v) => Ok(v),
            _ => bail!("expected u8 tensor"),
        }
    }
}

/// One named tensor: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorEntry {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl TensorEntry {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn u8(shape: &[usize], data: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data: TensorData::U8(data) }
    }
}

fn read_exact(r: &mut impl Read, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).context("truncated STDI file")?;
    Ok(buf)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    Ok(u32::from_le_bytes(read_exact(r, 4)?.try_into().unwrap()))
}

/// Load a whole container into a name-ordered map.
pub fn load_tensors(path: impl AsRef<Path>) -> Result<BTreeMap<String, TensorEntry>> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let magic = read_exact(&mut f, 4)?;
    if magic != MAGIC {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let count = read_u32(&mut f)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = u16::from_le_bytes(read_exact(&mut f, 2)?.try_into().unwrap());
        let name = String::from_utf8(read_exact(&mut f, nlen as usize)?)
            .context("tensor name not utf-8")?;
        let hdr = read_exact(&mut f, 2)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let n: usize = shape.iter().product();
        let data = match dtype {
            0 => {
                let raw = read_exact(&mut f, n * 4)?;
                TensorData::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            1 => {
                let raw = read_exact(&mut f, n * 4)?;
                TensorData::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            2 => TensorData::U8(read_exact(&mut f, n)?),
            d => bail!("{}: unknown dtype code {d} for {name}", path.display()),
        };
        out.insert(name, TensorEntry { shape, data });
    }
    Ok(out)
}

/// Write a container (deterministic order: map iteration order).
pub fn save_tensors(
    path: impl AsRef<Path>,
    tensors: &BTreeMap<String, TensorEntry>,
) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        let dtype = match t.data {
            TensorData::F32(_) => 0u8,
            TensorData::I32(_) => 1,
            TensorData::U8(_) => 2,
        };
        f.write_all(&[dtype, t.shape.len() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::U8(v) => f.write_all(v)?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_dtypes() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("t.bin");
        let mut m = BTreeMap::new();
        m.insert("f".into(), TensorEntry::f32(&[2, 3], vec![1., -2., 3., 4., 5.5, -6.]));
        m.insert("i".into(), TensorEntry::i32(&[4], vec![-1, 0, 7, i32::MAX]));
        m.insert("u".into(), TensorEntry::u8(&[2, 2], vec![0, 127, 200, 255]));
        save_tensors(&p, &m).unwrap();
        let back = load_tensors(&p).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("bad.bin");
        std::fs::write(&p, b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").unwrap();
        let e = load_tensors(&p).unwrap_err().to_string();
        assert!(e.contains("bad magic"), "{e}");
    }

    #[test]
    fn bad_version_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("v.bin");
        let mut bytes = MAGIC.to_vec();
        bytes.extend(99u32.to_le_bytes());
        bytes.extend(0u32.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let e = load_tensors(&p).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
    }

    #[test]
    fn truncated_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("t.bin");
        let mut m = BTreeMap::new();
        m.insert("x".into(), TensorEntry::f32(&[8], vec![0.0; 8]));
        save_tensors(&p, &m).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 5]).unwrap();
        let e = load_tensors(&p).unwrap_err().to_string();
        assert!(e.contains("truncated"), "{e}");
    }

    #[test]
    fn empty_container() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("e.bin");
        save_tensors(&p, &BTreeMap::new()).unwrap();
        assert!(load_tensors(&p).unwrap().is_empty());
    }

    #[test]
    fn typed_accessors() {
        let t = TensorEntry::f32(&[1], vec![2.0]);
        assert_eq!(t.data.as_f32().unwrap(), &[2.0]);
        assert!(t.data.as_i32().is_err());
        assert!(t.data.as_u8().is_err());
    }
}
