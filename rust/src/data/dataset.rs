//! Typed views over the python-produced artifacts:
//! `weights.bin` (trained LeNet-5 parameters), `dataset.bin` (held-out
//! test set) and `golden.bin` (cross-language reference I/O).

use super::tensorio::{load_tensors, TensorEntry};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Held-out test set (28×28 u8 images + labels).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// (N, 28, 28) raw u8 images.
    pub images: Vec<u8>,
    pub labels: Vec<u8>,
    pub n: usize,
    pub h: usize,
    pub w: usize,
}

impl Dataset {
    /// Image `i` as a normalized, 32×32 zero-padded NCHW tensor
    /// `(1, 1, 32, 32)` — LeNet-5's canonical input.
    pub fn image32(&self, i: usize) -> Tensor {
        assert!(i < self.n, "image index {i} out of {}", self.n);
        let mut out = vec![0f32; 32 * 32];
        let src = &self.images[i * self.h * self.w..(i + 1) * self.h * self.w];
        let (py, px) = ((32 - self.h) / 2, (32 - self.w) / 2);
        for y in 0..self.h {
            for x in 0..self.w {
                out[(y + py) * 32 + x + px] = src[y * self.w + x] as f32 / 255.0;
            }
        }
        Tensor::new(&[1, 1, 32, 32], out)
    }

    /// A batch of images `[start, start+n)` as `(n, 1, 32, 32)`.
    pub fn batch32(&self, start: usize, n: usize) -> Tensor {
        let mut data = Vec::with_capacity(n * 32 * 32);
        for i in start..start + n {
            data.extend_from_slice(self.image32(i % self.n).data());
        }
        Tensor::new(&[n, 1, 32, 32], data)
    }
}

/// Cross-language golden I/O: ref-path logits for 32 fixed inputs.
#[derive(Debug, Clone)]
pub struct Golden {
    /// (N, 1, 32, 32).
    pub inputs: Tensor,
    /// (N, 10).
    pub logits: Tensor,
    /// Training loss curve (one value per epoch) — the E2E training record.
    pub loss_curve: Vec<f32>,
}

fn to_tensor(name: &str, e: &TensorEntry) -> Result<Tensor> {
    let data = e
        .data
        .as_f32()
        .with_context(|| format!("{name}: expected f32"))?
        .to_vec();
    Ok(Tensor::new(&e.shape, data))
}

/// Load trained LeNet-5 parameters keyed as in `python/compile/model.py`.
pub fn load_weights(path: impl AsRef<Path>) -> Result<HashMap<String, Tensor>> {
    let raw = load_tensors(&path)?;
    let mut out = HashMap::new();
    for (k, v) in &raw {
        out.insert(k.clone(), to_tensor(k, v)?);
    }
    for required in [
        "c1_w", "c1_b", "c3_w", "c3_b", "c5_w", "c5_b", "f6_w", "f6_b", "out_w", "out_b",
    ] {
        if !out.contains_key(required) {
            bail!("weights file missing {required}");
        }
    }
    Ok(out)
}

/// Load the held-out test set.
pub fn load_dataset(path: impl AsRef<Path>) -> Result<Dataset> {
    let raw = load_tensors(&path)?;
    let images = raw.get("images").context("dataset missing 'images'")?;
    let labels = raw.get("labels").context("dataset missing 'labels'")?;
    if images.shape.len() != 3 {
        bail!("images must be (N, H, W), got {:?}", images.shape);
    }
    let (n, h, w) = (images.shape[0], images.shape[1], images.shape[2]);
    if labels.shape != [n] {
        bail!("labels shape {:?} != [{n}]", labels.shape);
    }
    Ok(Dataset {
        images: images.data.as_u8()?.to_vec(),
        labels: labels.data.as_u8()?.to_vec(),
        n,
        h,
        w,
    })
}

/// Load the golden reference I/O.
pub fn load_golden(path: impl AsRef<Path>) -> Result<Golden> {
    let raw = load_tensors(&path)?;
    let inputs = to_tensor("inputs", raw.get("inputs").context("golden missing inputs")?)?;
    let logits = to_tensor("logits", raw.get("logits").context("golden missing logits")?)?;
    let loss_curve = raw
        .get("loss_curve")
        .map(|e| e.data.as_f32().map(|v| v.to_vec()))
        .transpose()?
        .unwrap_or_default();
    if inputs.shape()[0] != logits.shape()[0] {
        bail!("golden inputs/logits batch mismatch");
    }
    Ok(Golden { inputs, logits, loss_curve })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tensorio::{save_tensors, TensorEntry};
    use std::collections::BTreeMap;

    fn tiny_dataset(dir: &std::path::Path) -> std::path::PathBuf {
        let p = dir.join("ds.bin");
        let mut m = BTreeMap::new();
        // 2 images 28x28: all-zero and all-255
        let mut imgs = vec![0u8; 28 * 28];
        imgs.extend(vec![255u8; 28 * 28]);
        m.insert("images".into(), TensorEntry::u8(&[2, 28, 28], imgs));
        m.insert("labels".into(), TensorEntry::u8(&[2], vec![3, 8]));
        save_tensors(&p, &m).unwrap();
        p
    }

    #[test]
    fn dataset_pad_and_normalize() {
        let dir = crate::util::TempDir::new().unwrap();
        let ds = load_dataset(tiny_dataset(dir.path())).unwrap();
        assert_eq!(ds.n, 2);
        let t0 = ds.image32(0);
        assert_eq!(t0.shape(), &[1, 1, 32, 32]);
        assert!(t0.data().iter().all(|&v| v == 0.0));
        let t1 = ds.image32(1);
        // padding ring is zero, interior is 1.0
        assert_eq!(t1.at(&[0, 0, 0, 0]), 0.0);
        assert_eq!(t1.at(&[0, 0, 2, 2]), 1.0);
        assert_eq!(t1.at(&[0, 0, 29, 29]), 1.0);
        assert_eq!(t1.at(&[0, 0, 31, 31]), 0.0);
    }

    #[test]
    fn batch_wraps_around() {
        let dir = crate::util::TempDir::new().unwrap();
        let ds = load_dataset(tiny_dataset(dir.path())).unwrap();
        let b = ds.batch32(1, 3); // images 1, 0, 1
        assert_eq!(b.shape(), &[3, 1, 32, 32]);
        assert_eq!(b.at(&[0, 0, 16, 16]), 1.0);
        assert_eq!(b.at(&[1, 0, 16, 16]), 0.0);
        assert_eq!(b.at(&[2, 0, 16, 16]), 1.0);
    }

    #[test]
    fn missing_keys_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("empty.bin");
        save_tensors(&p, &BTreeMap::new()).unwrap();
        assert!(load_dataset(&p).is_err());
        assert!(load_golden(&p).is_err());
        assert!(load_weights(&p).is_err());
    }

    #[test]
    fn weights_require_all_params() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("w.bin");
        let mut m = BTreeMap::new();
        m.insert("c1_w".into(), TensorEntry::f32(&[1], vec![0.0]));
        save_tensors(&p, &m).unwrap();
        let e = load_weights(&p).unwrap_err().to_string();
        assert!(e.contains("missing"), "{e}");
    }
}
