//! Pure-rust CNN inference engine.
//!
//! Serves three roles in the reproduction:
//!
//! 1. **Second numerical oracle** — integration tests check it against the
//!    python golden files and against the PJRT/XLA path, closing the
//!    cross-language loop.
//! 2. **Exact op accounting** — every layer reports its add/sub/mul counts
//!    ([`OpCounts`]), which is what Table 1 / Fig 7 are made of.
//! 3. **Timing substrate for Fig 1** — the AlexNet per-layer profile is
//!    measured on this engine (`examples/alexnet_profile.rs`).
//!
//! The engine is deliberately straightforward NCHW f32; the optimized
//! serving path is the AOT/PJRT artifact, not this module.

pub mod layers;
mod models;
mod ops;
pub mod params;

pub use layers::{Activation, Layer, LayerKind};
pub use models::{
    alexnet, grouped_mixer, lenet5, lenet5_from_params, lenet5_try_from_params, vgg_small, Model,
    PairedModel,
};
pub use ops::{ForwardCounts, OpCounts};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn lenet5_shapes_end_to_end() {
        let m = lenet5();
        let x = Tensor::zeros(&[2, 1, 32, 32]);
        let (y, _) = m.forward(&x);
        assert_eq!(y.shape(), &[2, 10]);
    }

    #[test]
    fn lenet5_conv_macs_match_table1_baseline() {
        // The rounding-0 row of Table 1 is fixed by geometry: 405 600.
        let m = lenet5();
        let x = Tensor::zeros(&[1, 1, 32, 32]);
        let (_, counts) = m.forward(&x);
        let conv_muls: u64 = counts
            .per_layer
            .iter()
            .filter(|(name, _)| name.starts_with('c'))
            .map(|(_, c)| c.muls)
            .sum();
        assert_eq!(conv_muls, 405_600);
    }

    #[test]
    fn alexnet_builds_and_runs() {
        let m = alexnet();
        let x = Tensor::zeros(&[1, 3, 227, 227]);
        let (y, counts) = m.forward(&x);
        assert_eq!(y.shape(), &[1, 1000]);
        // Ungrouped AlexNet conv MACs ≈ 1.08 G (the original's grouped
        // convs would halve conv2/4/5 to ≈ 0.67 G) — sanity band.
        let conv_muls: u64 = counts
            .per_layer
            .iter()
            .filter(|(n, _)| n.starts_with("conv"))
            .map(|(_, c)| c.muls)
            .sum();
        assert!(
            conv_muls > 1_000_000_000 && conv_muls < 1_150_000_000,
            "{conv_muls}"
        );
    }
}
