//! Model definitions: a composable [`Model`] (sequence of layers) plus the
//! two networks the paper uses — LeNet-5 (the evaluation target, Fig 2)
//! and AlexNet (the motivation figure, Fig 1). [`PairedModel`] is a model
//! compiled to the subtractor representation, executing its conv layers
//! on a shared [`ConvEngine`].

use super::layers::{Activation, Layer, LayerKind};
use super::ops::{ForwardCounts, OpCounts};
use super::params::{bias_key, weight_key};
use crate::accel::ConvEngine;
use crate::error::SubaccelError;
use crate::exec::{CompiledNet, PlanExecutor};
use crate::tensor::Tensor;
use crate::util::Rng;
use std::collections::HashMap;
use std::fmt;
use std::sync::Mutex;

/// A sequential CNN.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Model {
    pub fn new(name: &str, layers: Vec<Layer>) -> Self {
        Self { name: name.to_string(), layers }
    }

    /// Full forward pass with per-layer op accounting. Activations
    /// ping-pong between one reusable scratch pair instead of allocating
    /// a fresh tensor per layer.
    pub fn forward(&self, x: &Tensor) -> (Tensor, ForwardCounts) {
        let mut counts = ForwardCounts::default();
        let mut cur = x.data().to_vec();
        let mut shape = x.shape().to_vec();
        let mut spare: Vec<f32> = Vec::new();
        for layer in &self.layers {
            let (next_shape, c) = layer.forward_into(&cur, &shape, &mut spare);
            counts.push(&layer.name, c);
            std::mem::swap(&mut cur, &mut spare);
            shape = next_shape;
        }
        (Tensor::new(&shape, cur), counts)
    }

    /// Forward pass, discarding counts.
    pub fn infer(&self, x: &Tensor) -> Tensor {
        self.forward(x).0
    }

    /// Per-layer wall-clock profile (layer name, seconds, counts) — the
    /// measurement behind the Fig-1 reproduction. Same scratch-pair
    /// execution as [`Model::forward`], so layer timings exclude
    /// per-layer allocation noise.
    pub fn profile(&self, x: &Tensor) -> Vec<(String, f64, OpCounts)> {
        let mut cur = x.data().to_vec();
        let mut shape = x.shape().to_vec();
        let mut spare: Vec<f32> = Vec::new();
        let mut out = Vec::new();
        for layer in &self.layers {
            let t0 = std::time::Instant::now();
            let (next_shape, c) = layer.forward_into(&cur, &shape, &mut spare);
            out.push((layer.name.clone(), t0.elapsed().as_secs_f64(), c));
            std::mem::swap(&mut cur, &mut spare);
            shape = next_shape;
        }
        out
    }

    /// Conv layers as `(name, weight, bias, output positions)` — the
    /// inputs the paper's preprocessor operates on.
    pub fn conv_layers(&self, input: &[usize]) -> Vec<ConvLayerInfo> {
        let mut shape = input.to_vec();
        let mut infos = Vec::new();
        for layer in &self.layers {
            match &layer.kind {
                LayerKind::Conv2d { weight, bias, stride, pad_h, pad_w, .. } => {
                    let (h, w) = (shape[2] + 2 * pad_h, shape[3] + 2 * pad_w);
                    let (kh, kw) = (weight.shape()[2], weight.shape()[3]);
                    let oh = (h - kh) / stride + 1;
                    let ow = (w - kw) / stride + 1;
                    infos.push(ConvLayerInfo {
                        name: layer.name.clone(),
                        weight: weight.clone(),
                        bias: bias.clone(),
                        out_positions: oh * ow,
                    });
                    shape = vec![shape[0], weight.shape()[0], oh, ow];
                }
                LayerKind::AvgPool { k } => {
                    shape = vec![shape[0], shape[1], shape[2] / k, shape[3] / k];
                }
                LayerKind::MaxPool { k, stride, pad } => {
                    shape = vec![
                        shape[0],
                        shape[1],
                        (shape[2] + 2 * pad - k) / stride + 1,
                        (shape[3] + 2 * pad - k) / stride + 1,
                    ];
                }
                LayerKind::Flatten | LayerKind::Dense { .. } => {}
            }
        }
        infos
    }

    /// Replace a conv layer's weights (used to install modified weights).
    pub fn set_conv_weights(&mut self, name: &str, w: Tensor) {
        for layer in &mut self.layers {
            if layer.name == name {
                if let LayerKind::Conv2d { weight, .. } = &mut layer.kind {
                    assert_eq!(weight.shape(), w.shape(), "weight shape for {name}");
                    *weight = w;
                    return;
                }
            }
        }
        panic!("no conv layer named {name}");
    }
}

/// A [`Model`] compiled to the paper's paired representation — a thin
/// wrapper over the plan/execute split in [`crate::exec`]: compile runs
/// Algorithm 1 once into a [`CompiledNet`]; each input shape then gets a
/// lazily compiled [`crate::exec::ExecutionPlan`] executor, cached so
/// repeat shapes reuse its ping-pong scratch buffers. Execution goes
/// through a caller-supplied [`ConvEngine`], so one engine (and its
/// worker pool + scratch) serves the whole network — and can be shared
/// across models, e.g. per coordinator replica.
pub struct PairedModel {
    net: CompiledNet,
    /// One executor per seen input shape (interior-mutable so the
    /// `&self` forward API of the pre-plan era keeps working).
    execs: Mutex<HashMap<Vec<usize>, PlanExecutor>>,
}

impl Clone for PairedModel {
    fn clone(&self) -> Self {
        // executors are per-instance scratch; the clone re-plans lazily
        Self { net: self.net.clone(), execs: Mutex::new(HashMap::new()) }
    }
}

impl fmt::Debug for PairedModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PairedModel").field("net", &self.net).finish_non_exhaustive()
    }
}

impl PairedModel {
    /// Compile every conv layer of `model` at the given rounding size.
    pub fn compile(model: &Model, rounding: f32) -> Self {
        Self { net: CompiledNet::compile(model, rounding), execs: Mutex::new(HashMap::new()) }
    }

    pub fn name(&self) -> &str {
        self.net.name()
    }

    pub fn rounding(&self) -> f32 {
        self.net.rounding()
    }

    /// The shape-independent compiled network (for callers that want to
    /// plan shapes themselves, e.g. ahead-of-time warming).
    pub fn compiled(&self) -> &CompiledNet {
        &self.net
    }

    /// Total combined pairs across all conv layers.
    pub fn total_pairs(&self) -> usize {
        self.net.total_pairs()
    }

    /// Per-conv-layer pair counts `(name, pairs)`.
    pub fn pairs_per_conv(&self) -> Vec<(String, usize)> {
        self.net.pairs_per_conv()
    }

    /// Full forward pass on the given engine, with per-layer op
    /// accounting (conv layers report paired sub/MAC counts). Runs on
    /// the cached plan executor for `x`'s shape, compiling it on first
    /// sight of the shape.
    pub fn forward_with(
        &self,
        engine: &ConvEngine,
        x: &Tensor,
    ) -> Result<(Tensor, ForwardCounts), SubaccelError> {
        let mut execs = self.execs.lock().expect("plan cache lock");
        if !execs.contains_key(x.shape()) {
            let exec = self.net.plan(x.shape())?.into_executor();
            execs.insert(x.shape().to_vec(), exec);
        }
        let exec = execs.get_mut(x.shape()).expect("just inserted");
        exec.forward(engine, x)
    }

    /// Forward pass on the given engine, discarding counts.
    pub fn infer_with(&self, engine: &ConvEngine, x: &Tensor) -> Result<Tensor, SubaccelError> {
        Ok(self.forward_with(engine, x)?.0)
    }

    /// Per-step wall-clock profile `(name, seconds, counts)` of one
    /// forward on the given engine — the paired counterpart of
    /// [`Model::profile`], routed through the plan-level
    /// [`PlanExecutor::profile`] so both paths report identical
    /// per-step instrumentation (same step names, static counts).
    /// Runs on the cached plan executor for `x`'s shape.
    pub fn profile_with(
        &self,
        engine: &ConvEngine,
        x: &Tensor,
    ) -> Result<Vec<(String, f64, OpCounts)>, SubaccelError> {
        let mut execs = self.execs.lock().expect("plan cache lock");
        if !execs.contains_key(x.shape()) {
            let exec = self.net.plan(x.shape())?.into_executor();
            execs.insert(x.shape().to_vec(), exec);
        }
        let exec = execs.get_mut(x.shape()).expect("just inserted");
        exec.profile(engine, x)
    }
}

/// Geometry + parameters of one conv layer, as consumed by Algorithm 1.
#[derive(Debug, Clone)]
pub struct ConvLayerInfo {
    pub name: String,
    pub weight: Tensor,
    pub bias: Tensor,
    /// OH·OW for a single image — each weight is used this many times.
    pub out_positions: usize,
}

fn randt(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect())
}

/// LeNet-5 (paper Fig 2) with Glorot-ish random weights (seeded).
/// Use [`lenet5_from_params`] to install trained weights from
/// `artifacts/weights.bin`.
pub fn lenet5() -> Model {
    let mut rng = Rng::seed_from_u64(7);
    let conv = |rng: &mut Rng, name: &str, co: usize, ci: usize, k: usize| {
        let scale = (6.0 / ((ci * k * k + co) as f32)).sqrt();
        Layer::new(
            name,
            LayerKind::Conv2d {
                weight: randt(rng, &[co, ci, k, k], scale),
                bias: Tensor::zeros(&[co]),
                stride: 1,
                pad_h: 0,
                pad_w: 0,
                groups: 1,
            },
            Activation::Tanh,
        )
    };
    let layers = vec![
        conv(&mut rng, "c1", 6, 1, 5),
        Layer::new("s2", LayerKind::AvgPool { k: 2 }, Activation::None),
        conv(&mut rng, "c3", 16, 6, 5),
        Layer::new("s4", LayerKind::AvgPool { k: 2 }, Activation::None),
        conv(&mut rng, "c5", 120, 16, 5),
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        Layer::new(
            "f6",
            LayerKind::Dense {
                weight: randt(&mut rng, &[84, 120], 0.17),
                bias: Tensor::zeros(&[84]),
            },
            Activation::Tanh,
        ),
        Layer::new(
            "out",
            LayerKind::Dense {
                weight: randt(&mut rng, &[10, 84], 0.25),
                bias: Tensor::zeros(&[10]),
            },
            Activation::None,
        ),
    ];
    Model::new("lenet5", layers)
}

/// LeNet-5 with trained parameters (keys as in
/// [`crate::nn::params::PARAM_NAMES`]). Panics on missing parameters;
/// use [`lenet5_try_from_params`] for a typed error instead.
pub fn lenet5_from_params(params: &HashMap<String, Tensor>) -> Model {
    lenet5_try_from_params(params).unwrap_or_else(|e| panic!("{e}"))
}

/// [`lenet5_from_params`] with missing keys reported as
/// [`SubaccelError::InvalidConfig`] — the serving paths (runtime,
/// coordinator) build models from caller-supplied weight maps and must
/// not panic on a bad artifact.
pub fn lenet5_try_from_params(params: &HashMap<String, Tensor>) -> Result<Model, SubaccelError> {
    let get = |k: String| {
        params.get(&k).cloned().ok_or_else(|| SubaccelError::InvalidConfig {
            field: "weights",
            reason: format!("missing param {k}"),
        })
    };
    let conv = |name: &str| -> Result<Layer, SubaccelError> {
        Ok(Layer::new(
            name,
            LayerKind::Conv2d {
                weight: get(weight_key(name))?,
                bias: get(bias_key(name))?,
                stride: 1,
                pad_h: 0,
                pad_w: 0,
                groups: 1,
            },
            Activation::Tanh,
        ))
    };
    let dense = |name: &str, act: Activation| -> Result<Layer, SubaccelError> {
        Ok(Layer::new(
            name,
            LayerKind::Dense { weight: get(weight_key(name))?, bias: get(bias_key(name))? },
            act,
        ))
    };
    let layers = vec![
        conv("c1")?,
        Layer::new("s2", LayerKind::AvgPool { k: 2 }, Activation::None),
        conv("c3")?,
        Layer::new("s4", LayerKind::AvgPool { k: 2 }, Activation::None),
        conv("c5")?,
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        dense("f6", Activation::Tanh)?,
        dense("out", Activation::None)?,
    ];
    Ok(Model::new("lenet5", layers))
}

/// AlexNet (Krizhevsky 2012) with random weights — only its *structure*
/// matters here: it drives the Fig-1 per-layer timing reproduction.
pub fn alexnet() -> Model {
    let mut rng = Rng::seed_from_u64(23);
    let conv = |rng: &mut Rng,
                name: &str,
                co: usize,
                ci: usize,
                k: usize,
                stride: usize,
                pad: usize| {
        let scale = (2.0 / ((ci * k * k) as f32)).sqrt();
        Layer::new(
            name,
            LayerKind::Conv2d {
                weight: randt(rng, &[co, ci, k, k], scale),
                bias: Tensor::zeros(&[co]),
                stride,
                pad_h: pad,
                pad_w: pad,
                groups: 1,
            },
            Activation::Relu,
        )
    };
    let dense = |rng: &mut Rng, name: &str, o: usize, i: usize| {
        Layer::new(
            name,
            LayerKind::Dense {
                weight: randt(rng, &[o, i], (1.0 / i as f32).sqrt()),
                bias: Tensor::zeros(&[o]),
            },
            Activation::Relu,
        )
    };
    let layers = vec![
        conv(&mut rng, "conv1", 96, 3, 11, 4, 0),
        Layer::new("pool1", LayerKind::MaxPool { k: 3, stride: 2, pad: 0 }, Activation::None),
        conv(&mut rng, "conv2", 256, 96, 5, 1, 2),
        Layer::new("pool2", LayerKind::MaxPool { k: 3, stride: 2, pad: 0 }, Activation::None),
        conv(&mut rng, "conv3", 384, 256, 3, 1, 1),
        conv(&mut rng, "conv4", 384, 384, 3, 1, 1),
        conv(&mut rng, "conv5", 256, 384, 3, 1, 1),
        Layer::new("pool5", LayerKind::MaxPool { k: 3, stride: 2, pad: 0 }, Activation::None),
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        dense(&mut rng, "fc6", 4096, 256 * 6 * 6),
        dense(&mut rng, "fc7", 4096, 4096),
        Layer::new(
            "fc8",
            LayerKind::Dense {
                weight: randt(&mut rng, &[1000, 4096], 0.015),
                bias: Tensor::zeros(&[1000]),
            },
            Activation::None,
        ),
    ];
    Model::new("alexnet", layers)
}

/// VGG-style small network (3×3 conv stacks, 32×32×3 input — CIFAR-class)
/// with seeded random weights. Used by the generality bench: the pairing
/// statistics depend only on the weight distribution, which random init
/// shares with trained nets (zero-centred, near-symmetric).
pub fn vgg_small() -> Model {
    let mut rng = Rng::seed_from_u64(31);
    let conv = |rng: &mut Rng, name: &str, co: usize, ci: usize| {
        let scale = (2.0 / ((ci * 9) as f32)).sqrt();
        Layer::new(
            name,
            LayerKind::Conv2d {
                weight: randt(rng, &[co, ci, 3, 3], scale),
                bias: Tensor::zeros(&[co]),
                stride: 1,
                pad: 1,
            },
            Activation::Relu,
        )
    };
    let pool = |name: &str| {
        Layer::new(name, LayerKind::MaxPool { k: 2, stride: 2, pad: 0 }, Activation::None)
    };
    let layers = vec![
        conv(&mut rng, "conv1_1", 32, 3),
        conv(&mut rng, "conv1_2", 32, 32),
        pool("pool1"),
        conv(&mut rng, "conv2_1", 64, 32),
        conv(&mut rng, "conv2_2", 64, 64),
        pool("pool2"),
        conv(&mut rng, "conv3_1", 128, 64),
        conv(&mut rng, "conv3_2", 128, 128),
        pool("pool3"),
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        Layer::new(
            "fc1",
            LayerKind::Dense {
                weight: randt(&mut rng, &[256, 128 * 4 * 4], 0.03),
                bias: Tensor::zeros(&[256]),
            },
            Activation::Relu,
        ),
        Layer::new(
            "fc2",
            LayerKind::Dense {
                weight: randt(&mut rng, &[10, 256], 0.06),
                bias: Tensor::zeros(&[10]),
            },
            Activation::None,
        ),
    ];
    Model::new("vgg_small", layers)
}

/// A small network exercising every generalized geometry at once:
/// grouped convs, non-square kernels, asymmetric padding, and padded
/// stride-2 max pooling. No paper model looks like this — it exists so
/// the plan pipeline, engine kernels, and benches cover the full
/// geometry space, not just LeNet/AlexNet shapes.
///
/// Input `(B, 8, 20, 16)`:
/// - `gconv1`: 16×(8/2)×3×5, groups 2, stride 1, pad (1, 2) → `(16, 20, 16)`
/// - `pool1`:  max 3×3, stride 2, pad 1                      → `(16, 10, 8)`
/// - `gconv2`: 32×(16/4)×5×3, groups 4, stride 2, pad (2, 1) → `(32, 5, 4)`
/// - flatten + dense → 10 logits
pub fn grouped_mixer() -> Model {
    let mut rng = Rng::seed_from_u64(47);
    let conv = |rng: &mut Rng,
                name: &str,
                co: usize,
                cipg: usize,
                kh: usize,
                kw: usize,
                stride: usize,
                pad_h: usize,
                pad_w: usize,
                groups: usize| {
        let scale = (2.0 / ((cipg * kh * kw) as f32)).sqrt();
        Layer::new(
            name,
            LayerKind::Conv2d {
                weight: randt(rng, &[co, cipg, kh, kw], scale),
                bias: randt(rng, &[co], 0.1),
                stride,
                pad_h,
                pad_w,
                groups,
            },
            Activation::Relu,
        )
    };
    let layers = vec![
        conv(&mut rng, "gconv1", 16, 4, 3, 5, 1, 1, 2, 2),
        Layer::new("pool1", LayerKind::MaxPool { k: 3, stride: 2, pad: 1 }, Activation::None),
        conv(&mut rng, "gconv2", 32, 4, 5, 3, 2, 2, 1, 4),
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        Layer::new(
            "fc",
            LayerKind::Dense {
                weight: randt(&mut rng, &[10, 32 * 5 * 4], 0.05),
                bias: Tensor::zeros(&[10]),
            },
            Activation::None,
        ),
    ];
    Model::new("grouped_mixer", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg_small_shapes() {
        let m = vgg_small();
        let x = Tensor::zeros(&[1, 3, 32, 32]);
        let (y, counts) = m.forward(&x);
        assert_eq!(y.shape(), &[1, 10]);
        // 3×3 pad-1 stacks: conv MACs ≈ 38.8 M
        let conv_muls: u64 = counts
            .per_layer
            .iter()
            .filter(|(n, _)| n.starts_with("conv"))
            .map(|(_, c)| c.muls)
            .sum();
        assert!(conv_muls > 35_000_000 && conv_muls < 45_000_000, "{conv_muls}");
    }

    #[test]
    fn grouped_mixer_shapes() {
        let m = grouped_mixer();
        let x = Tensor::zeros(&[2, 8, 20, 16]);
        let (y, _) = m.forward(&x);
        assert_eq!(y.shape(), &[2, 10]);
        let infos = m.conv_layers(&[1, 8, 20, 16]);
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].out_positions, 20 * 16);
        assert_eq!(infos[1].out_positions, 5 * 4);
    }

    #[test]
    fn conv_layers_geometry_lenet() {
        let m = lenet5();
        let infos = m.conv_layers(&[1, 1, 32, 32]);
        assert_eq!(infos.len(), 3);
        assert_eq!(infos[0].out_positions, 28 * 28);
        assert_eq!(infos[1].out_positions, 10 * 10);
        assert_eq!(infos[2].out_positions, 1);
        let total: usize = infos
            .iter()
            .map(|i| i.weight.len() * i.out_positions)
            .sum();
        assert_eq!(total, 405_600);
    }

    #[test]
    fn set_conv_weights_roundtrip() {
        let mut m = lenet5();
        let w = Tensor::full(&[6, 1, 5, 5], 0.5);
        m.set_conv_weights("c1", w.clone());
        let infos = m.conv_layers(&[1, 1, 32, 32]);
        assert_eq!(infos[0].weight, w);
    }

    #[test]
    #[should_panic(expected = "no conv layer")]
    fn set_unknown_layer_panics() {
        lenet5().set_conv_weights("nope", Tensor::zeros(&[1, 1, 1, 1]));
    }

    #[test]
    fn lenet_deterministic_seed() {
        let a = lenet5().infer(&Tensor::full(&[1, 1, 32, 32], 0.3));
        let b = lenet5().infer(&Tensor::full(&[1, 1, 32, 32], 0.3));
        assert_eq!(a, b);
    }

    #[test]
    fn paired_profile_reports_plan_steps_and_static_counts() {
        let m = lenet5();
        let pm = PairedModel::compile(&m, 0.05);
        let mut rng = Rng::seed_from_u64(19);
        let x = randt(&mut rng, &[1, 1, 32, 32], 1.0);
        let prof = pm.profile_with(&ConvEngine::serial(), &x).unwrap();
        // same step names as the plan path, and the dense profile's
        // layer granularity (8 LeNet-5 steps)
        assert_eq!(prof.len(), 8);
        let plan = pm.compiled().plan(&[1, 1, 32, 32]).unwrap();
        for ((name, secs, counts), step) in prof.iter().zip(plan.steps()) {
            assert_eq!(name, step.name());
            assert_eq!(*counts, step.counts());
            assert!(*secs >= 0.0);
        }
        // summed profile counts == forward counts (both are the static
        // plan counts — profiling changes instrumentation, not math)
        let (_, fwd) = pm.forward_with(&ConvEngine::serial(), &x).unwrap();
        let profiled_subs: u64 = prof.iter().map(|(_, _, c)| c.subs).sum();
        let fwd_subs: u64 = fwd.per_layer.iter().map(|(_, c)| c.subs).sum();
        assert_eq!(profiled_subs, fwd_subs);
    }

    #[test]
    fn paired_lenet_matches_dense_with_modified_weights() {
        let m = lenet5();
        let rounding = 0.15;
        let pm = PairedModel::compile(&m, rounding);
        assert!(pm.total_pairs() > 0, "rounding 0.15 should combine pairs");
        assert_eq!(pm.pairs_per_conv().len(), 3);

        // oracle: the dense model with snapped ("modified") weights
        let mut snapped = m.clone();
        for info in m.conv_layers(&[1, 1, 32, 32]) {
            let lp = crate::accel::LayerPairing::from_weights(&info.weight, rounding);
            snapped.set_conv_weights(&info.name, lp.modified_weights(&info.weight));
        }

        let mut rng = Rng::seed_from_u64(41);
        let x = randt(&mut rng, &[2, 1, 32, 32], 1.0);
        let eng = ConvEngine::new(2).unwrap();
        let (y, counts) = pm.forward_with(&eng, &x).unwrap();
        let (want, _) = snapped.forward(&x);
        assert_eq!(y.shape(), want.shape());
        assert!(y.max_abs_diff(&want) < 1e-4, "{}", y.max_abs_diff(&want));
        // the paired path replaced muls with subs
        let subs: u64 = counts.per_layer.iter().map(|(_, c)| c.subs).sum();
        assert!(subs > 0);
    }

    #[test]
    fn paired_forward_is_engine_invariant() {
        let m = lenet5();
        let pm = PairedModel::compile(&m, 0.1);
        let x = Tensor::full(&[1, 1, 32, 32], 0.25);
        let y1 = pm.infer_with(&ConvEngine::serial(), &x).unwrap();
        let y3 = pm.infer_with(&ConvEngine::new(3).unwrap(), &x).unwrap();
        assert_eq!(y1, y3, "thread count changed paired-model numerics");
    }
}
