//! Model definitions: a composable [`Model`] (sequence of layers) plus the
//! two networks the paper uses — LeNet-5 (the evaluation target, Fig 2)
//! and AlexNet (the motivation figure, Fig 1). [`PairedModel`] is a model
//! compiled to the subtractor representation, executing its conv layers
//! on a shared [`ConvEngine`].

use super::layers::{Activation, Layer, LayerKind};
use super::ops::{ForwardCounts, OpCounts};
use crate::accel::{ConvEngine, SubConv2d};
use crate::error::SubaccelError;
use crate::tensor::Tensor;
use crate::util::Rng;
use std::collections::HashMap;

/// A sequential CNN.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Model {
    pub fn new(name: &str, layers: Vec<Layer>) -> Self {
        Self { name: name.to_string(), layers }
    }

    /// Full forward pass with per-layer op accounting.
    pub fn forward(&self, x: &Tensor) -> (Tensor, ForwardCounts) {
        let mut counts = ForwardCounts::default();
        let mut h = x.clone();
        for layer in &self.layers {
            let (out, c) = layer.forward(&h);
            counts.push(&layer.name, c);
            h = out;
        }
        (h, counts)
    }

    /// Forward pass, discarding counts.
    pub fn infer(&self, x: &Tensor) -> Tensor {
        self.forward(x).0
    }

    /// Per-layer wall-clock profile (layer name, seconds, counts) — the
    /// measurement behind the Fig-1 reproduction.
    pub fn profile(&self, x: &Tensor) -> Vec<(String, f64, OpCounts)> {
        let mut h = x.clone();
        let mut out = Vec::new();
        for layer in &self.layers {
            let t0 = std::time::Instant::now();
            let (next, c) = layer.forward(&h);
            out.push((layer.name.clone(), t0.elapsed().as_secs_f64(), c));
            h = next;
        }
        out
    }

    /// Conv layers as `(name, weight, bias, output positions)` — the
    /// inputs the paper's preprocessor operates on.
    pub fn conv_layers(&self, input: &[usize]) -> Vec<ConvLayerInfo> {
        let mut shape = input.to_vec();
        let mut infos = Vec::new();
        for layer in &self.layers {
            match &layer.kind {
                LayerKind::Conv2d { weight, bias, stride, pad } => {
                    let (h, w) = (shape[2] + 2 * pad, shape[3] + 2 * pad);
                    let (kh, kw) = (weight.shape()[2], weight.shape()[3]);
                    let oh = (h - kh) / stride + 1;
                    let ow = (w - kw) / stride + 1;
                    infos.push(ConvLayerInfo {
                        name: layer.name.clone(),
                        weight: weight.clone(),
                        bias: bias.clone(),
                        out_positions: oh * ow,
                    });
                    shape = vec![shape[0], weight.shape()[0], oh, ow];
                }
                LayerKind::AvgPool { k } => {
                    shape = vec![shape[0], shape[1], shape[2] / k, shape[3] / k];
                }
                LayerKind::MaxPool { k, stride } => {
                    shape = vec![
                        shape[0],
                        shape[1],
                        (shape[2] - k) / stride + 1,
                        (shape[3] - k) / stride + 1,
                    ];
                }
                LayerKind::Flatten | LayerKind::Dense { .. } => {}
            }
        }
        infos
    }

    /// Replace a conv layer's weights (used to install modified weights).
    pub fn set_conv_weights(&mut self, name: &str, w: Tensor) {
        for layer in &mut self.layers {
            if layer.name == name {
                if let LayerKind::Conv2d { weight, .. } = &mut layer.kind {
                    assert_eq!(weight.shape(), w.shape(), "weight shape for {name}");
                    *weight = w;
                    return;
                }
            }
        }
        panic!("no conv layer named {name}");
    }
}

/// One layer of a [`PairedModel`]: conv layers carry a compiled
/// subtractor unit, everything else runs the ordinary dense code.
#[derive(Debug, Clone)]
enum PairedLayer {
    Sub { name: String, unit: SubConv2d, act: Activation },
    Plain(Layer),
}

/// A [`Model`] compiled to the paper's paired representation: every conv
/// layer becomes a [`SubConv2d`] (preprocessed once at the configured
/// rounding), pooling/dense/activation layers are shared with the dense
/// path. Execution goes through a caller-supplied [`ConvEngine`], so one
/// engine (and its worker pool + scratch) serves the whole network — and
/// can be shared across models, e.g. per coordinator replica.
#[derive(Debug, Clone)]
pub struct PairedModel {
    name: String,
    layers: Vec<PairedLayer>,
    rounding: f32,
}

impl PairedModel {
    /// Compile every conv layer of `model` at the given rounding size.
    pub fn compile(model: &Model, rounding: f32) -> Self {
        let layers = model
            .layers
            .iter()
            .map(|layer| match &layer.kind {
                LayerKind::Conv2d { weight, bias, stride, pad } => PairedLayer::Sub {
                    name: layer.name.clone(),
                    unit: SubConv2d::compile_geo(weight, bias, rounding, *stride, *pad),
                    act: layer.act,
                },
                _ => PairedLayer::Plain(layer.clone()),
            })
            .collect();
        Self { name: model.name.clone(), layers, rounding }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    /// Total combined pairs across all conv layers.
    pub fn total_pairs(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                PairedLayer::Sub { unit, .. } => unit.total_pairs(),
                PairedLayer::Plain(_) => 0,
            })
            .sum()
    }

    /// Per-conv-layer pair counts `(name, pairs)`.
    pub fn pairs_per_conv(&self) -> Vec<(String, usize)> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                PairedLayer::Sub { name, unit, .. } => {
                    Some((name.clone(), unit.total_pairs()))
                }
                PairedLayer::Plain(_) => None,
            })
            .collect()
    }

    /// Full forward pass on the given engine, with per-layer op
    /// accounting (conv layers report paired sub/MAC counts).
    pub fn forward_with(
        &self,
        engine: &ConvEngine,
        x: &Tensor,
    ) -> Result<(Tensor, ForwardCounts), SubaccelError> {
        let mut counts = ForwardCounts::default();
        let mut h = x.clone();
        for layer in &self.layers {
            match layer {
                PairedLayer::Sub { name, unit, act } => {
                    let (mut out, mut c) = unit.forward_with(engine, &h)?;
                    c.activations += act.apply(&mut out);
                    counts.push(name, c);
                    h = out;
                }
                PairedLayer::Plain(layer) => {
                    let (out, c) = layer.forward(&h);
                    counts.push(&layer.name, c);
                    h = out;
                }
            }
        }
        Ok((h, counts))
    }

    /// Forward pass on the given engine, discarding counts.
    pub fn infer_with(&self, engine: &ConvEngine, x: &Tensor) -> Result<Tensor, SubaccelError> {
        Ok(self.forward_with(engine, x)?.0)
    }
}

/// Geometry + parameters of one conv layer, as consumed by Algorithm 1.
#[derive(Debug, Clone)]
pub struct ConvLayerInfo {
    pub name: String,
    pub weight: Tensor,
    pub bias: Tensor,
    /// OH·OW for a single image — each weight is used this many times.
    pub out_positions: usize,
}

fn randt(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect())
}

/// LeNet-5 (paper Fig 2) with Glorot-ish random weights (seeded).
/// Use [`lenet5_from_params`] to install trained weights from
/// `artifacts/weights.bin`.
pub fn lenet5() -> Model {
    let mut rng = Rng::seed_from_u64(7);
    let conv = |rng: &mut Rng, name: &str, co: usize, ci: usize, k: usize| {
        let scale = (6.0 / ((ci * k * k + co) as f32)).sqrt();
        Layer::new(
            name,
            LayerKind::Conv2d {
                weight: randt(rng, &[co, ci, k, k], scale),
                bias: Tensor::zeros(&[co]),
                stride: 1,
                pad: 0,
            },
            Activation::Tanh,
        )
    };
    let layers = vec![
        conv(&mut rng, "c1", 6, 1, 5),
        Layer::new("s2", LayerKind::AvgPool { k: 2 }, Activation::None),
        conv(&mut rng, "c3", 16, 6, 5),
        Layer::new("s4", LayerKind::AvgPool { k: 2 }, Activation::None),
        conv(&mut rng, "c5", 120, 16, 5),
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        Layer::new(
            "f6",
            LayerKind::Dense {
                weight: randt(&mut rng, &[84, 120], 0.17),
                bias: Tensor::zeros(&[84]),
            },
            Activation::Tanh,
        ),
        Layer::new(
            "out",
            LayerKind::Dense {
                weight: randt(&mut rng, &[10, 84], 0.25),
                bias: Tensor::zeros(&[10]),
            },
            Activation::None,
        ),
    ];
    Model::new("lenet5", layers)
}

/// LeNet-5 with trained parameters (keys as in `python/compile/model.py`).
pub fn lenet5_from_params(params: &HashMap<String, Tensor>) -> Model {
    let get = |k: &str| params.get(k).unwrap_or_else(|| panic!("missing param {k}")).clone();
    let conv = |name: &str, w: &str, b: &str| {
        Layer::new(
            name,
            LayerKind::Conv2d { weight: get(w), bias: get(b), stride: 1, pad: 0 },
            Activation::Tanh,
        )
    };
    let layers = vec![
        conv("c1", "c1_w", "c1_b"),
        Layer::new("s2", LayerKind::AvgPool { k: 2 }, Activation::None),
        conv("c3", "c3_w", "c3_b"),
        Layer::new("s4", LayerKind::AvgPool { k: 2 }, Activation::None),
        conv("c5", "c5_w", "c5_b"),
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        Layer::new(
            "f6",
            LayerKind::Dense { weight: get("f6_w"), bias: get("f6_b") },
            Activation::Tanh,
        ),
        Layer::new(
            "out",
            LayerKind::Dense { weight: get("out_w"), bias: get("out_b") },
            Activation::None,
        ),
    ];
    Model::new("lenet5", layers)
}

/// AlexNet (Krizhevsky 2012) with random weights — only its *structure*
/// matters here: it drives the Fig-1 per-layer timing reproduction.
pub fn alexnet() -> Model {
    let mut rng = Rng::seed_from_u64(23);
    let conv = |rng: &mut Rng,
                name: &str,
                co: usize,
                ci: usize,
                k: usize,
                stride: usize,
                pad: usize| {
        let scale = (2.0 / ((ci * k * k) as f32)).sqrt();
        Layer::new(
            name,
            LayerKind::Conv2d {
                weight: randt(rng, &[co, ci, k, k], scale),
                bias: Tensor::zeros(&[co]),
                stride,
                pad,
            },
            Activation::Relu,
        )
    };
    let dense = |rng: &mut Rng, name: &str, o: usize, i: usize| {
        Layer::new(
            name,
            LayerKind::Dense {
                weight: randt(rng, &[o, i], (1.0 / i as f32).sqrt()),
                bias: Tensor::zeros(&[o]),
            },
            Activation::Relu,
        )
    };
    let layers = vec![
        conv(&mut rng, "conv1", 96, 3, 11, 4, 0),
        Layer::new("pool1", LayerKind::MaxPool { k: 3, stride: 2 }, Activation::None),
        conv(&mut rng, "conv2", 256, 96, 5, 1, 2),
        Layer::new("pool2", LayerKind::MaxPool { k: 3, stride: 2 }, Activation::None),
        conv(&mut rng, "conv3", 384, 256, 3, 1, 1),
        conv(&mut rng, "conv4", 384, 384, 3, 1, 1),
        conv(&mut rng, "conv5", 256, 384, 3, 1, 1),
        Layer::new("pool5", LayerKind::MaxPool { k: 3, stride: 2 }, Activation::None),
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        dense(&mut rng, "fc6", 4096, 256 * 6 * 6),
        dense(&mut rng, "fc7", 4096, 4096),
        Layer::new(
            "fc8",
            LayerKind::Dense {
                weight: randt(&mut rng, &[1000, 4096], 0.015),
                bias: Tensor::zeros(&[1000]),
            },
            Activation::None,
        ),
    ];
    Model::new("alexnet", layers)
}

/// VGG-style small network (3×3 conv stacks, 32×32×3 input — CIFAR-class)
/// with seeded random weights. Used by the generality bench: the pairing
/// statistics depend only on the weight distribution, which random init
/// shares with trained nets (zero-centred, near-symmetric).
pub fn vgg_small() -> Model {
    let mut rng = Rng::seed_from_u64(31);
    let conv = |rng: &mut Rng, name: &str, co: usize, ci: usize| {
        let scale = (2.0 / ((ci * 9) as f32)).sqrt();
        Layer::new(
            name,
            LayerKind::Conv2d {
                weight: randt(rng, &[co, ci, 3, 3], scale),
                bias: Tensor::zeros(&[co]),
                stride: 1,
                pad: 1,
            },
            Activation::Relu,
        )
    };
    let pool = |name: &str| Layer::new(name, LayerKind::MaxPool { k: 2, stride: 2 }, Activation::None);
    let layers = vec![
        conv(&mut rng, "conv1_1", 32, 3),
        conv(&mut rng, "conv1_2", 32, 32),
        pool("pool1"),
        conv(&mut rng, "conv2_1", 64, 32),
        conv(&mut rng, "conv2_2", 64, 64),
        pool("pool2"),
        conv(&mut rng, "conv3_1", 128, 64),
        conv(&mut rng, "conv3_2", 128, 128),
        pool("pool3"),
        Layer::new("flat", LayerKind::Flatten, Activation::None),
        Layer::new(
            "fc1",
            LayerKind::Dense {
                weight: randt(&mut rng, &[256, 128 * 4 * 4], 0.03),
                bias: Tensor::zeros(&[256]),
            },
            Activation::Relu,
        ),
        Layer::new(
            "fc2",
            LayerKind::Dense {
                weight: randt(&mut rng, &[10, 256], 0.06),
                bias: Tensor::zeros(&[10]),
            },
            Activation::None,
        ),
    ];
    Model::new("vgg_small", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg_small_shapes() {
        let m = vgg_small();
        let x = Tensor::zeros(&[1, 3, 32, 32]);
        let (y, counts) = m.forward(&x);
        assert_eq!(y.shape(), &[1, 10]);
        // 3×3 pad-1 stacks: conv MACs ≈ 38.8 M
        let conv_muls: u64 = counts
            .per_layer
            .iter()
            .filter(|(n, _)| n.starts_with("conv"))
            .map(|(_, c)| c.muls)
            .sum();
        assert!(conv_muls > 35_000_000 && conv_muls < 45_000_000, "{conv_muls}");
    }

    #[test]
    fn conv_layers_geometry_lenet() {
        let m = lenet5();
        let infos = m.conv_layers(&[1, 1, 32, 32]);
        assert_eq!(infos.len(), 3);
        assert_eq!(infos[0].out_positions, 28 * 28);
        assert_eq!(infos[1].out_positions, 10 * 10);
        assert_eq!(infos[2].out_positions, 1);
        let total: usize = infos
            .iter()
            .map(|i| i.weight.len() * i.out_positions)
            .sum();
        assert_eq!(total, 405_600);
    }

    #[test]
    fn set_conv_weights_roundtrip() {
        let mut m = lenet5();
        let w = Tensor::full(&[6, 1, 5, 5], 0.5);
        m.set_conv_weights("c1", w.clone());
        let infos = m.conv_layers(&[1, 1, 32, 32]);
        assert_eq!(infos[0].weight, w);
    }

    #[test]
    #[should_panic(expected = "no conv layer")]
    fn set_unknown_layer_panics() {
        lenet5().set_conv_weights("nope", Tensor::zeros(&[1, 1, 1, 1]));
    }

    #[test]
    fn lenet_deterministic_seed() {
        let a = lenet5().infer(&Tensor::full(&[1, 1, 32, 32], 0.3));
        let b = lenet5().infer(&Tensor::full(&[1, 1, 32, 32], 0.3));
        assert_eq!(a, b);
    }

    #[test]
    fn paired_lenet_matches_dense_with_modified_weights() {
        let m = lenet5();
        let rounding = 0.15;
        let pm = PairedModel::compile(&m, rounding);
        assert!(pm.total_pairs() > 0, "rounding 0.15 should combine pairs");
        assert_eq!(pm.pairs_per_conv().len(), 3);

        // oracle: the dense model with snapped ("modified") weights
        let mut snapped = m.clone();
        for info in m.conv_layers(&[1, 1, 32, 32]) {
            let lp = crate::accel::LayerPairing::from_weights(&info.weight, rounding);
            snapped.set_conv_weights(&info.name, lp.modified_weights(&info.weight));
        }

        let mut rng = Rng::seed_from_u64(41);
        let x = randt(&mut rng, &[2, 1, 32, 32], 1.0);
        let eng = ConvEngine::new(2).unwrap();
        let (y, counts) = pm.forward_with(&eng, &x).unwrap();
        let (want, _) = snapped.forward(&x);
        assert_eq!(y.shape(), want.shape());
        assert!(y.max_abs_diff(&want) < 1e-4, "{}", y.max_abs_diff(&want));
        // the paired path replaced muls with subs
        let subs: u64 = counts.per_layer.iter().map(|(_, c)| c.subs).sum();
        assert!(subs > 0);
    }

    #[test]
    fn paired_forward_is_engine_invariant() {
        let m = lenet5();
        let pm = PairedModel::compile(&m, 0.1);
        let x = Tensor::full(&[1, 1, 32, 32], 0.25);
        let y1 = pm.infer_with(&ConvEngine::serial(), &x).unwrap();
        let y3 = pm.infer_with(&ConvEngine::new(3).unwrap(), &x).unwrap();
        assert_eq!(y1, y3, "thread count changed paired-model numerics");
    }
}
