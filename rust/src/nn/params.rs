//! LeNet-5 parameter wire contract — the single registry of weight-map
//! keys shared by every consumer of `artifacts/weights.bin`.
//!
//! Three places used to carry their own copy of this knowledge (the
//! runtime executor's install order, the paired CPU path's conv keys,
//! and the model builder in [`crate::nn`]); they all import from here
//! now, so a renamed parameter is a one-file change on the rust side.
//! The authoritative producer is `python/compile/model.py::PARAM_NAMES`
//! — the order below is the wire order of the flat `weights.bin` blob
//! and must match it exactly.

/// Flat wire order of the LeNet-5 parameters in `weights.bin`.
///
/// Must match `python/compile/model.py::PARAM_NAMES`.
pub const PARAM_NAMES: [&str; 10] = [
    "c1_w", "c1_b", "c3_w", "c3_b", "c5_w", "c5_b", "f6_w", "f6_b", "out_w", "out_b",
];

/// Conv layers subject to Algorithm 1 preprocessing, as
/// `(weight key, layer name)` — the layers whose weights get
/// sorted/paired/rounded before execution.
pub const CONV_KEYS: [(&str, &str); 3] = [("c1_w", "c1"), ("c3_w", "c3"), ("c5_w", "c5")];

/// LeNet-5 conv layer names in network order (paper Fig. 2).
pub const CONV_LAYERS: [&str; 3] = ["c1", "c3", "c5"];

/// Weight-map key for a layer's kernel/weight matrix.
pub fn weight_key(layer: &str) -> String {
    format!("{layer}_w")
}

/// Weight-map key for a layer's bias vector.
pub fn bias_key(layer: &str) -> String {
    format!("{layer}_b")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_keys_agree_with_layer_names() {
        for ((wk, name), expect) in CONV_KEYS.iter().zip(CONV_LAYERS) {
            assert_eq!(*name, expect);
            assert_eq!(*wk, weight_key(name));
        }
    }

    #[test]
    fn every_conv_key_is_a_wire_param() {
        for (wk, name) in CONV_KEYS {
            assert!(PARAM_NAMES.contains(&wk));
            assert!(PARAM_NAMES.contains(&bias_key(name).as_str()));
        }
    }

    #[test]
    fn wire_order_pairs_weight_then_bias() {
        for pair in PARAM_NAMES.chunks(2) {
            assert!(pair[0].ends_with("_w") && pair[1].ends_with("_b"), "bad pair {pair:?}");
            assert_eq!(pair[0].trim_end_matches("_w"), pair[1].trim_end_matches("_b"));
        }
    }
}
