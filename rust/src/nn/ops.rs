//! Operation accounting shared by the dense engine and the subtractor
//! unit. Table-1 semantics (see DESIGN.md): a MAC is 1 multiply + 1
//! accumulate-add; a combined pair is 1 subtract + 1 multiply + 1
//! accumulate-add; bias adds and activation evaluations are tracked
//! separately and excluded from the paper's headline columns.

use std::ops::{Add, AddAssign};

/// Arithmetic-operation counts for one layer or one whole inference.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OpCounts {
    /// Multiplications on the MAC/pair datapath.
    pub muls: u64,
    /// Accumulate additions on the MAC/pair datapath.
    pub adds: u64,
    /// Input subtractions on the pair datapath (the paper's contribution).
    pub subs: u64,
    /// Bias additions (excluded from Table 1, tracked for the cost model).
    pub bias_adds: u64,
    /// Non-linearity evaluations (tanh/relu/softmax elements).
    pub activations: u64,
}

impl OpCounts {
    /// Table-1 "Total" column: adds + subs + muls.
    pub fn table1_total(&self) -> u64 {
        self.adds + self.subs + self.muls
    }

    /// Counts for a dense conv/FC layer of `weights` weights applied at
    /// `positions` output positions (baseline: every weight is a MAC).
    pub fn dense_layer(weights: u64, positions: u64, biases: u64) -> Self {
        OpCounts {
            muls: weights * positions,
            adds: weights * positions,
            subs: 0,
            bias_adds: biases,
            activations: 0,
        }
    }

    /// Counts for a paired layer: `pairs` combined pairs and `unpaired`
    /// plain weights per filter set, applied at `positions` positions.
    pub fn paired_layer(pairs: u64, unpaired: u64, positions: u64, biases: u64) -> Self {
        OpCounts {
            // each pair: 1 sub + 1 mul + 1 accumulate; each unpaired: 1 MAC
            muls: (pairs + unpaired) * positions,
            adds: (pairs + unpaired) * positions,
            subs: pairs * positions,
            bias_adds: biases,
            activations: 0,
        }
    }
}

impl Add for OpCounts {
    type Output = OpCounts;
    fn add(self, o: OpCounts) -> OpCounts {
        OpCounts {
            muls: self.muls + o.muls,
            adds: self.adds + o.adds,
            subs: self.subs + o.subs,
            bias_adds: self.bias_adds + o.bias_adds,
            activations: self.activations + o.activations,
        }
    }
}

impl AddAssign for OpCounts {
    fn add_assign(&mut self, o: OpCounts) {
        *self = *self + o;
    }
}

/// Per-layer counts for a full forward pass.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ForwardCounts {
    pub per_layer: Vec<(String, OpCounts)>,
}

impl ForwardCounts {
    pub fn push(&mut self, name: &str, c: OpCounts) {
        self.per_layer.push((name.to_string(), c));
    }

    pub fn total(&self) -> OpCounts {
        self.per_layer.iter().fold(OpCounts::default(), |a, (_, c)| a + *c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_layer_is_macs() {
        let c = OpCounts::dense_layer(25, 784, 6);
        assert_eq!(c.muls, 19_600);
        assert_eq!(c.adds, 19_600);
        assert_eq!(c.subs, 0);
        assert_eq!(c.bias_adds, 6);
        assert_eq!(c.table1_total(), 39_200);
    }

    #[test]
    fn paired_layer_identity() {
        // 10 weights, 3 pairs → 4 unpaired; at 7 positions
        let base = OpCounts::dense_layer(10, 7, 0);
        let p = OpCounts::paired_layer(3, 4, 7, 0);
        assert_eq!(p.subs, 21);
        assert_eq!(p.muls, base.muls - 21);
        assert_eq!(p.adds, base.adds - 21);
        assert_eq!(p.table1_total(), base.table1_total() - 21);
    }

    #[test]
    fn sum_and_total() {
        let mut f = ForwardCounts::default();
        f.push("a", OpCounts::dense_layer(2, 3, 1));
        f.push("b", OpCounts::paired_layer(1, 0, 3, 1));
        let t = f.total();
        assert_eq!(t.muls, 6 + 3);
        assert_eq!(t.subs, 3);
        assert_eq!(t.bias_adds, 2);
    }
}
