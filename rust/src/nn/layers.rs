//! Layer implementations for the pure-rust engine: convolution (stride /
//! zero-padding), pooling, dense, activations. Each layer's `forward`
//! returns both the output tensor and its [`OpCounts`].

use super::ops::OpCounts;
use crate::tensor::Tensor;

/// Non-linearities used by the bundled models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Tanh,
    Relu,
}

impl Activation {
    /// Apply in place; returns the activation-op count. `pub(crate)` so
    /// the paired forward ([`crate::nn::PairedModel`]) shares the exact
    /// same non-linearity code as the dense path.
    pub(crate) fn apply(&self, x: &mut Tensor) -> u64 {
        match self {
            Activation::None => 0,
            Activation::Tanh => {
                for v in x.data_mut() {
                    *v = v.tanh();
                }
                x.len() as u64
            }
            Activation::Relu => {
                for v in x.data_mut() {
                    *v = v.max(0.0);
                }
                x.len() as u64
            }
        }
    }
}

/// The structural part of a layer (weights live inside the variants).
#[derive(Debug, Clone)]
pub enum LayerKind {
    /// `weight (Cout, Cin, kh, kw)`, `bias (Cout,)`, stride, zero padding.
    Conv2d { weight: Tensor, bias: Tensor, stride: usize, pad: usize },
    /// k×k average pooling with stride k.
    AvgPool { k: usize },
    /// k×k max pooling with the given stride (AlexNet uses overlapping 3/2).
    MaxPool { k: usize, stride: usize },
    /// `weight (Out, In)`, `bias (Out,)`.
    Dense { weight: Tensor, bias: Tensor },
    /// NCHW → (N, C·H·W).
    Flatten,
}

/// A named layer with an activation applied after the linear part.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub act: Activation,
}

impl Layer {
    pub fn new(name: &str, kind: LayerKind, act: Activation) -> Self {
        Self { name: name.to_string(), kind, act }
    }

    /// Run the layer; returns output and op counts (activation included).
    pub fn forward(&self, x: &Tensor) -> (Tensor, OpCounts) {
        let (mut out, mut counts) = match &self.kind {
            LayerKind::Conv2d { weight, bias, stride, pad } => {
                conv2d(x, weight, bias, *stride, *pad)
            }
            LayerKind::AvgPool { k } => avgpool(x, *k),
            LayerKind::MaxPool { k, stride } => maxpool(x, *k, *stride),
            LayerKind::Dense { weight, bias } => dense(x, weight, bias),
            LayerKind::Flatten => {
                let n = x.shape()[0];
                let rest: usize = x.shape()[1..].iter().product();
                (x.clone().reshape(&[n, rest]), OpCounts::default())
            }
        };
        counts.activations += self.act.apply(&mut out);
        (out, counts)
    }
}

/// Valid/padded strided convolution, NCHW × OIHW → NCHW.
pub fn conv2d(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    stride: usize,
    pad: usize,
) -> (Tensor, OpCounts) {
    let (bs, cin, h, win) = dims4(x);
    let (cout, wcin, kh, kw) = dims4(w);
    assert_eq!(cin, wcin, "channel mismatch {cin} vs {wcin}");
    assert_eq!(b.len(), cout, "bias length");
    let (hp, wp) = (h + 2 * pad, win + 2 * pad);
    assert!(hp >= kh && wp >= kw, "kernel larger than padded input");
    let oh = (hp - kh) / stride + 1;
    let ow = (wp - kw) / stride + 1;

    let mut out = vec![0f32; bs * cout * oh * ow];
    let xd = x.data();
    let wd = w.data();
    let bd = b.data();

    if pad == 0 {
        // Fast path (hot in every sweep): contiguous row dot-products, no
        // per-tap bounds checks. ~2× over the general path (see
        // EXPERIMENTS.md §Perf).
        for bi in 0..bs {
            for co in 0..cout {
                let wbase = co * cin * kh * kw;
                for oy in 0..oh {
                    let iy0 = oy * stride;
                    for ox in 0..ow {
                        let ix0 = ox * stride;
                        let mut acc = bd[co];
                        for ci in 0..cin {
                            let xc = (bi * cin + ci) * h * win;
                            let wc = wbase + ci * kh * kw;
                            for dy in 0..kh {
                                let xrow = &xd[xc + (iy0 + dy) * win + ix0..][..kw];
                                let wrow = &wd[wc + dy * kw..][..kw];
                                acc += xrow
                                    .iter()
                                    .zip(wrow)
                                    .map(|(a, b)| a * b)
                                    .sum::<f32>();
                            }
                        }
                        out[((bi * cout + co) * oh + oy) * ow + ox] = acc;
                    }
                }
            }
        }
    } else {
        for bi in 0..bs {
            for co in 0..cout {
                let wbase = co * cin * kh * kw;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = bd[co];
                        let iy0 = oy * stride;
                        let ix0 = ox * stride;
                        for ci in 0..cin {
                            let xc = (bi * cin + ci) * h * win;
                            let wc = wbase + ci * kh * kw;
                            for dy in 0..kh {
                                let iy = iy0 + dy;
                                if iy < pad || iy >= h + pad {
                                    continue;
                                }
                                let xrow = xc + (iy - pad) * win;
                                let wrow = wc + dy * kw;
                                for dx in 0..kw {
                                    let ix = ix0 + dx;
                                    if ix < pad || ix >= win + pad {
                                        continue;
                                    }
                                    acc += xd[xrow + (ix - pad)] * wd[wrow + dx];
                                }
                            }
                        }
                        out[((bi * cout + co) * oh + oy) * ow + ox] = acc;
                    }
                }
            }
        }
    }
    // Counting convention (paper): padded taps still occupy a MAC slot in
    // the accelerator schedule, so counts use the full kernel volume.
    let weights = (cout * cin * kh * kw) as u64;
    let positions = (bs * oh * ow) as u64;
    let counts = OpCounts::dense_layer(weights, positions, (bs * cout * oh * ow) as u64);
    (Tensor::new(&[bs, cout, oh, ow], out), counts)
}

/// Plain 2×2 average pooling (no counts) — convenience for custom
/// pipelines like the subtractor-unit forward in the CLI.
pub fn avgpool2(x: &Tensor) -> Tensor {
    avgpool(x, 2).0
}

/// In-place tanh (no counts) — convenience for custom pipelines.
pub fn tanh_inplace(x: &mut Tensor) {
    for v in x.data_mut() {
        *v = v.tanh();
    }
}

/// Dense layer returning only the output (no counts).
pub fn dense_layer(x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
    dense(x, w, b).0
}

fn avgpool(x: &Tensor, k: usize) -> (Tensor, OpCounts) {
    let (bs, c, h, w) = dims4(x);
    assert!(h % k == 0 && w % k == 0, "avgpool {k} on {h}x{w}");
    let (oh, ow) = (h / k, w / k);
    let mut out = vec![0f32; bs * c * oh * ow];
    let xd = x.data();
    let inv = 1.0 / (k * k) as f32;
    for bi in 0..bs {
        for ci in 0..c {
            let base = (bi * c + ci) * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut s = 0f32;
                    for dy in 0..k {
                        for dx in 0..k {
                            s += xd[base + (oy * k + dy) * w + ox * k + dx];
                        }
                    }
                    out[((bi * c + ci) * oh + oy) * ow + ox] = s * inv;
                }
            }
        }
    }
    let counts = OpCounts {
        adds: (bs * c * oh * ow * (k * k - 1)) as u64,
        muls: (bs * c * oh * ow) as u64,
        ..Default::default()
    };
    (Tensor::new(&[bs, c, oh, ow], out), counts)
}

fn maxpool(x: &Tensor, k: usize, stride: usize) -> (Tensor, OpCounts) {
    let (bs, c, h, w) = dims4(x);
    assert!(h >= k && w >= k);
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let mut out = vec![0f32; bs * c * oh * ow];
    let xd = x.data();
    for bi in 0..bs {
        for ci in 0..c {
            let base = (bi * c + ci) * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..k {
                        for dx in 0..k {
                            m = m.max(xd[base + (oy * stride + dy) * w + ox * stride + dx]);
                        }
                    }
                    out[((bi * c + ci) * oh + oy) * ow + ox] = m;
                }
            }
        }
    }
    (Tensor::new(&[bs, c, oh, ow], out), OpCounts::default())
}

fn dense(x: &Tensor, w: &Tensor, b: &Tensor) -> (Tensor, OpCounts) {
    assert_eq!(x.ndim(), 2, "dense expects (B, In), got {:?}", x.shape());
    let (bs, nin) = (x.shape()[0], x.shape()[1]);
    let (nout, win) = (w.shape()[0], w.shape()[1]);
    assert_eq!(nin, win, "dense in-features {nin} vs {win}");
    let mut out = vec![0f32; bs * nout];
    let xd = x.data();
    let wd = w.data();
    for bi in 0..bs {
        let xrow = &xd[bi * nin..(bi + 1) * nin];
        for o in 0..nout {
            let wrow = &wd[o * nin..(o + 1) * nin];
            let mut acc = b.data()[o];
            for i in 0..nin {
                acc += xrow[i] * wrow[i];
            }
            out[bi * nout + o] = acc;
        }
    }
    let counts = OpCounts::dense_layer((nout * nin) as u64, bs as u64, (bs * nout) as u64);
    (Tensor::new(&[bs, nout], out), counts)
}

fn dims4(t: &Tensor) -> (usize, usize, usize, usize) {
    let s = t.shape();
    assert_eq!(s.len(), 4, "expected 4-D tensor, got {:?}", s);
    (s[0], s[1], s[2], s[3])
}

/// Row-wise softmax for a `(B, N)` tensor (used by examples for readable
/// confidences; not part of the counted datapath).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 2);
    let (b, n) = (x.shape()[0], x.shape()[1]);
    let mut out = vec![0f32; b * n];
    for bi in 0..b {
        let row = &x.data()[bi * n..(bi + 1) * n];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let exps: Vec<f32> = row.iter().map(|&v| (v - m).exp()).collect();
        let s: f32 = exps.iter().sum();
        for (o, e) in out[bi * n..(bi + 1) * n].iter_mut().zip(exps) {
            *o = e / s;
        }
    }
    Tensor::new(&[b, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_known_values() {
        // 1x1x3x3 input, single 2x2 ones kernel, bias 1 → window sums + 1
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32).collect());
        let w = Tensor::full(&[1, 1, 2, 2], 1.0);
        let b = Tensor::new(&[1], vec![1.0]);
        let (y, c) = conv2d(&x, &w, &b, 1, 0);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[9.0, 13.0, 21.0, 25.0]);
        assert_eq!(c.muls, 16);
        assert_eq!(c.adds, 16);
        assert_eq!(c.bias_adds, 4);
    }

    #[test]
    fn conv_stride_and_pad() {
        let x = Tensor::full(&[1, 1, 4, 4], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let b = Tensor::zeros(&[1]);
        let (y, _) = conv2d(&x, &w, &b, 2, 1);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        // corners of padded conv see 4 ones; pad=1 stride=2 grid
        assert_eq!(y.data(), &[4.0, 6.0, 6.0, 9.0]);
    }

    #[test]
    fn avgpool_known() {
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 3., 5., 7.]);
        let (y, _) = avgpool(&x, 2);
        assert_eq!(y.data(), &[4.0]);
    }

    #[test]
    fn maxpool_overlapping() {
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32).collect());
        let (y, _) = maxpool(&x, 3, 2);
        assert_eq!(y.data(), &[8.0]);
        let (y2, _) = maxpool(&x, 2, 1);
        assert_eq!(y2.data(), &[4., 5., 7., 8.]);
    }

    #[test]
    fn dense_known() {
        let x = Tensor::new(&[1, 3], vec![1., 2., 3.]);
        let w = Tensor::new(&[2, 3], vec![1., 0., 0., 0., 1., 1.]);
        let b = Tensor::new(&[2], vec![0.5, -0.5]);
        let (y, c) = dense(&x, &w, &b);
        assert_eq!(y.data(), &[1.5, 4.5]);
        assert_eq!(c.muls, 6);
    }

    #[test]
    fn activations() {
        let mut t = Tensor::new(&[3], vec![-1.0, 0.0, 1.0]);
        let n = Activation::Relu.apply(&mut t);
        assert_eq!(n, 3);
        assert_eq!(t.data(), &[0.0, 0.0, 1.0]);
        let mut t2 = Tensor::new(&[1], vec![0.0]);
        Activation::Tanh.apply(&mut t2);
        assert_eq!(t2.data(), &[0.0]);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., -1., 0., 1.]);
        let s = softmax_rows(&t);
        for bi in 0..2 {
            let sum: f32 = s.data()[bi * 3..(bi + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }
}
