//! Layer implementations for the pure-rust engine: convolution (stride /
//! zero-padding), pooling, dense, activations. Each layer's `forward`
//! returns both the output tensor and its [`OpCounts`].
//!
//! Every kernel has two entry points: an allocating one (`conv2d`,
//! `dense`, …) and a `_into` variant writing a caller-owned buffer. The
//! allocating forms are thin wrappers over the `_into` forms — same loop,
//! same summation order, bit-identical results — so whole-network callers
//! ([`crate::nn::Model::forward`], the [`crate::exec`] plan executor) can
//! ping-pong two scratch buffers instead of allocating per layer.

use super::ops::OpCounts;
use crate::tensor::Tensor;

/// Non-linearities used by the bundled models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Tanh,
    Relu,
}

impl Activation {
    /// Apply in place; returns the activation-op count. `pub(crate)` so
    /// the paired forward ([`crate::nn::PairedModel`]) shares the exact
    /// same non-linearity code as the dense path.
    pub(crate) fn apply(&self, x: &mut Tensor) -> u64 {
        self.apply_slice(x.data_mut())
    }

    /// [`Activation::apply`] on a raw slice — the entry point for
    /// activations living in scratch buffers ([`crate::exec`]).
    pub(crate) fn apply_slice(&self, xs: &mut [f32]) -> u64 {
        match self {
            Activation::None => 0,
            Activation::Tanh => {
                for v in xs.iter_mut() {
                    *v = v.tanh();
                }
                xs.len() as u64
            }
            Activation::Relu => {
                for v in xs.iter_mut() {
                    *v = v.max(0.0);
                }
                xs.len() as u64
            }
        }
    }
}

/// The structural part of a layer (weights live inside the variants).
#[derive(Debug, Clone)]
pub enum LayerKind {
    /// `weight (Cout, Cin/groups, kh, kw)`, `bias (Cout,)`, stride, per-axis
    /// zero padding, channel groups (1 = ordinary dense connectivity).
    Conv2d {
        weight: Tensor,
        bias: Tensor,
        stride: usize,
        pad_h: usize,
        pad_w: usize,
        groups: usize,
    },
    /// k×k average pooling with stride k.
    AvgPool { k: usize },
    /// k×k max pooling with the given stride and symmetric zero padding
    /// (AlexNet uses overlapping 3/2; padded windows skip out-of-bounds
    /// taps, i.e. the pad value is −∞).
    MaxPool { k: usize, stride: usize, pad: usize },
    /// `weight (Out, In)`, `bias (Out,)`.
    Dense { weight: Tensor, bias: Tensor },
    /// NCHW → (N, C·H·W).
    Flatten,
}

/// A named layer with an activation applied after the linear part.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub act: Activation,
}

impl Layer {
    pub fn new(name: &str, kind: LayerKind, act: Activation) -> Self {
        Self { name: name.to_string(), kind, act }
    }

    /// Run the layer; returns output and op counts (activation included).
    pub fn forward(&self, x: &Tensor) -> (Tensor, OpCounts) {
        let mut out = Vec::new();
        let (shape, counts) = self.forward_into(x.data(), x.shape(), &mut out);
        (Tensor::new(&shape, out), counts)
    }

    /// [`Layer::forward`] on raw slices into a caller-owned buffer
    /// (resized and fully overwritten; activation applied in place).
    /// `Model::forward`/`Model::profile` ping-pong two such buffers so a
    /// whole forward pass reuses the same pair of allocations.
    pub fn forward_into(
        &self,
        xd: &[f32],
        xshape: &[usize],
        out: &mut Vec<f32>,
    ) -> (Vec<usize>, OpCounts) {
        let (shape, mut counts) = match &self.kind {
            LayerKind::Conv2d { weight, bias, stride, pad_h, pad_w, groups } => {
                let (s, c) = conv2d_into(
                    xd,
                    xshape,
                    weight.data(),
                    weight.shape(),
                    bias.data(),
                    *stride,
                    *pad_h,
                    *pad_w,
                    *groups,
                    out,
                );
                (s.to_vec(), c)
            }
            LayerKind::AvgPool { k } => {
                let (s, c) = avgpool_into(xd, xshape, *k, out);
                (s.to_vec(), c)
            }
            LayerKind::MaxPool { k, stride, pad } => {
                let (s, c) = maxpool_into(xd, xshape, *k, *stride, *pad, out);
                (s.to_vec(), c)
            }
            LayerKind::Dense { weight, bias } => {
                let (s, c) =
                    dense_into(xd, xshape, weight.data(), weight.shape(), bias.data(), out);
                (s.to_vec(), c)
            }
            LayerKind::Flatten => {
                // pure row-major relabel NCHW → (N, C·H·W)
                out.clear();
                out.extend_from_slice(xd);
                let rest: usize = xshape[1..].iter().product();
                (vec![xshape[0], rest], OpCounts::default())
            }
        };
        counts.activations += self.act.apply_slice(out);
        (shape, counts)
    }
}

/// Valid/padded strided convolution, NCHW × OIHW → NCHW (symmetric
/// padding, dense connectivity — the historical signature; grouped or
/// asymmetric layers call [`conv2d_into`] directly).
pub fn conv2d(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    stride: usize,
    pad: usize,
) -> (Tensor, OpCounts) {
    let mut out = Vec::new();
    let (shape, counts) = conv2d_into(
        x.data(),
        x.shape(),
        w.data(),
        w.shape(),
        b.data(),
        stride,
        pad,
        pad,
        1,
        &mut out,
    );
    (Tensor::new(&shape, out), counts)
}

/// [`conv2d`] on raw slices into a caller-owned buffer (resized and fully
/// overwritten); returns the NCHW output shape alongside the counts.
/// Weight layout is grouped OIHW `(Cout, Cin/groups, kh, kw)`: output
/// channel `co` reads only the `Cin/groups` input channels of its group
/// `co / (Cout/groups)`.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into(
    xd: &[f32],
    xshape: &[usize],
    wd: &[f32],
    wshape: &[usize],
    bd: &[f32],
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    groups: usize,
    out: &mut Vec<f32>,
) -> ([usize; 4], OpCounts) {
    let (bs, cin, h, win) = dims4(xshape);
    let (cout, wcin, kh, kw) = dims4(wshape);
    assert!(groups >= 1, "groups must be at least 1");
    assert!(cout % groups == 0, "Cout {cout} not divisible into {groups} groups");
    assert_eq!(cin, wcin * groups, "channel mismatch {cin} vs {wcin}x{groups} groups");
    assert_eq!(bd.len(), cout, "bias length");
    let (hp, wp) = (h + 2 * pad_h, win + 2 * pad_w);
    assert!(hp >= kh && wp >= kw, "kernel larger than padded input");
    let oh = (hp - kh) / stride + 1;
    let ow = (wp - kw) / stride + 1;
    let cpg = cout / groups;

    out.resize(bs * cout * oh * ow, 0.0);

    if pad_h == 0 && pad_w == 0 {
        // Fast path (hot in every sweep): contiguous row dot-products, no
        // per-tap bounds checks. ~2× over the general path (see
        // EXPERIMENTS.md §Perf).
        // Loop nest interchanged to keep the weight row hoisted across a
        // whole output row — the same row-blocking idea as the paired
        // engine's microkernel (`accel::engine`). Each output element
        // still accumulates its (ci, dy) row dot-products in the same
        // order as the naive nest, so results are bit-identical.
        for bi in 0..bs {
            for co in 0..cout {
                let wbase = co * wcin * kh * kw;
                let c0 = (co / cpg) * wcin; // first input channel of co's group
                for oy in 0..oh {
                    let iy0 = oy * stride;
                    let orow = ((bi * cout + co) * oh + oy) * ow;
                    out[orow..orow + ow].fill(bd[co]);
                    for ci in 0..wcin {
                        let xc = (bi * cin + c0 + ci) * h * win;
                        let wc = wbase + ci * kh * kw;
                        for dy in 0..kh {
                            let xrow0 = xc + (iy0 + dy) * win;
                            let wrow = &wd[wc + dy * kw..][..kw];
                            for ox in 0..ow {
                                let xrow = &xd[xrow0 + ox * stride..][..kw];
                                out[orow + ox] += xrow
                                    .iter()
                                    .zip(wrow)
                                    .map(|(a, b)| a * b)
                                    .sum::<f32>();
                            }
                        }
                    }
                }
            }
        }
    } else {
        for bi in 0..bs {
            for co in 0..cout {
                let wbase = co * wcin * kh * kw;
                let c0 = (co / cpg) * wcin;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = bd[co];
                        let iy0 = oy * stride;
                        let ix0 = ox * stride;
                        for ci in 0..wcin {
                            let xc = (bi * cin + c0 + ci) * h * win;
                            let wc = wbase + ci * kh * kw;
                            for dy in 0..kh {
                                let iy = iy0 + dy;
                                if iy < pad_h || iy >= h + pad_h {
                                    continue;
                                }
                                let xrow = xc + (iy - pad_h) * win;
                                let wrow = wc + dy * kw;
                                for dx in 0..kw {
                                    let ix = ix0 + dx;
                                    if ix < pad_w || ix >= win + pad_w {
                                        continue;
                                    }
                                    acc += xd[xrow + (ix - pad_w)] * wd[wrow + dx];
                                }
                            }
                        }
                        out[((bi * cout + co) * oh + oy) * ow + ox] = acc;
                    }
                }
            }
        }
    }
    // Counting convention (paper): padded taps still occupy a MAC slot in
    // the accelerator schedule, so counts use the full (per-group) kernel
    // volume — for grouped layers that is Cout · Cin/groups · kh · kw.
    let weights = (cout * wcin * kh * kw) as u64;
    let positions = (bs * oh * ow) as u64;
    let counts = OpCounts::dense_layer(weights, positions, (bs * cout * oh * ow) as u64);
    ([bs, cout, oh, ow], counts)
}

/// Plain 2×2 average pooling (no counts) — convenience for custom
/// pipelines like the subtractor-unit forward in the CLI.
pub fn avgpool2(x: &Tensor) -> Tensor {
    avgpool(x, 2).0
}

/// In-place tanh (no counts) — convenience for custom pipelines.
pub fn tanh_inplace(x: &mut Tensor) {
    for v in x.data_mut() {
        *v = v.tanh();
    }
}

/// Dense layer returning only the output (no counts).
pub fn dense_layer(x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
    dense(x, w, b).0
}

fn avgpool(x: &Tensor, k: usize) -> (Tensor, OpCounts) {
    let mut out = Vec::new();
    let (shape, counts) = avgpool_into(x.data(), x.shape(), k, &mut out);
    (Tensor::new(&shape, out), counts)
}

/// k×k average pooling (stride k) on raw slices into a caller-owned
/// buffer; returns the NCHW output shape alongside the counts.
pub fn avgpool_into(
    xd: &[f32],
    xshape: &[usize],
    k: usize,
    out: &mut Vec<f32>,
) -> ([usize; 4], OpCounts) {
    let (bs, c, h, w) = dims4(xshape);
    assert!(h % k == 0 && w % k == 0, "avgpool {k} on {h}x{w}");
    let (oh, ow) = (h / k, w / k);
    out.resize(bs * c * oh * ow, 0.0);
    let inv = 1.0 / (k * k) as f32;
    for bi in 0..bs {
        for ci in 0..c {
            let base = (bi * c + ci) * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut s = 0f32;
                    for dy in 0..k {
                        for dx in 0..k {
                            s += xd[base + (oy * k + dy) * w + ox * k + dx];
                        }
                    }
                    out[((bi * c + ci) * oh + oy) * ow + ox] = s * inv;
                }
            }
        }
    }
    let counts = OpCounts {
        adds: (bs * c * oh * ow * (k * k - 1)) as u64,
        muls: (bs * c * oh * ow) as u64,
        ..Default::default()
    };
    ([bs, c, oh, ow], counts)
}

fn maxpool(x: &Tensor, k: usize, stride: usize, pad: usize) -> (Tensor, OpCounts) {
    let mut out = Vec::new();
    let (shape, counts) = maxpool_into(x.data(), x.shape(), k, stride, pad, &mut out);
    (Tensor::new(&shape, out), counts)
}

/// k×k max pooling with the given stride and symmetric zero padding on
/// raw slices into a caller-owned buffer; returns the NCHW output shape
/// and (zero) counts. Out-of-bounds taps are skipped, which is the
/// standard −∞-padding semantics; `pad < k` is required so every window
/// overlaps the real input.
pub fn maxpool_into(
    xd: &[f32],
    xshape: &[usize],
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut Vec<f32>,
) -> ([usize; 4], OpCounts) {
    let (bs, c, h, w) = dims4(xshape);
    assert!(k >= 1 && stride >= 1, "maxpool kernel/stride must be at least 1");
    assert!(pad < k, "maxpool pad {pad} must be smaller than kernel {k}");
    assert!(h + 2 * pad >= k && w + 2 * pad >= k, "kernel larger than padded input");
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    out.resize(bs * c * oh * ow, 0.0);
    for bi in 0..bs {
        for ci in 0..c {
            let base = (bi * c + ci) * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    // pad < k guarantees at least one in-bounds tap, so m
                    // never stays −∞.
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..k {
                        let iy = oy * stride + dy;
                        if iy < pad || iy >= h + pad {
                            continue;
                        }
                        for dx in 0..k {
                            let ix = ox * stride + dx;
                            if ix < pad || ix >= w + pad {
                                continue;
                            }
                            m = m.max(xd[base + (iy - pad) * w + (ix - pad)]);
                        }
                    }
                    out[((bi * c + ci) * oh + oy) * ow + ox] = m;
                }
            }
        }
    }
    ([bs, c, oh, ow], OpCounts::default())
}

fn dense(x: &Tensor, w: &Tensor, b: &Tensor) -> (Tensor, OpCounts) {
    let mut out = Vec::new();
    let (shape, counts) = dense_into(x.data(), x.shape(), w.data(), w.shape(), b.data(), &mut out);
    (Tensor::new(&shape, out), counts)
}

/// Dense layer on raw slices into a caller-owned buffer; returns the
/// `(B, Out)` output shape alongside the counts.
pub fn dense_into(
    xd: &[f32],
    xshape: &[usize],
    wd: &[f32],
    wshape: &[usize],
    bd: &[f32],
    out: &mut Vec<f32>,
) -> ([usize; 2], OpCounts) {
    assert_eq!(xshape.len(), 2, "dense expects (B, In), got {xshape:?}");
    let (bs, nin) = (xshape[0], xshape[1]);
    let (nout, win) = (wshape[0], wshape[1]);
    assert_eq!(nin, win, "dense in-features {nin} vs {win}");
    out.resize(bs * nout, 0.0);
    for bi in 0..bs {
        let xrow = &xd[bi * nin..(bi + 1) * nin];
        for o in 0..nout {
            let wrow = &wd[o * nin..(o + 1) * nin];
            let mut acc = bd[o];
            for i in 0..nin {
                acc += xrow[i] * wrow[i];
            }
            out[bi * nout + o] = acc;
        }
    }
    let counts = OpCounts::dense_layer((nout * nin) as u64, bs as u64, (bs * nout) as u64);
    ([bs, nout], counts)
}

fn dims4(s: &[usize]) -> (usize, usize, usize, usize) {
    assert_eq!(s.len(), 4, "expected 4-D tensor, got {s:?}");
    (s[0], s[1], s[2], s[3])
}

/// Row-wise softmax for a `(B, N)` tensor (used by examples for readable
/// confidences; not part of the counted datapath).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 2);
    let (b, n) = (x.shape()[0], x.shape()[1]);
    let mut out = vec![0f32; b * n];
    for bi in 0..b {
        let row = &x.data()[bi * n..(bi + 1) * n];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let exps: Vec<f32> = row.iter().map(|&v| (v - m).exp()).collect();
        let s: f32 = exps.iter().sum();
        for (o, e) in out[bi * n..(bi + 1) * n].iter_mut().zip(exps) {
            *o = e / s;
        }
    }
    Tensor::new(&[b, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_known_values() {
        // 1x1x3x3 input, single 2x2 ones kernel, bias 1 → window sums + 1
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32).collect());
        let w = Tensor::full(&[1, 1, 2, 2], 1.0);
        let b = Tensor::new(&[1], vec![1.0]);
        let (y, c) = conv2d(&x, &w, &b, 1, 0);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[9.0, 13.0, 21.0, 25.0]);
        assert_eq!(c.muls, 16);
        assert_eq!(c.adds, 16);
        assert_eq!(c.bias_adds, 4);
    }

    #[test]
    fn conv_stride_and_pad() {
        let x = Tensor::full(&[1, 1, 4, 4], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let b = Tensor::zeros(&[1]);
        let (y, _) = conv2d(&x, &w, &b, 2, 1);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        // corners of padded conv see 4 ones; pad=1 stride=2 grid
        assert_eq!(y.data(), &[4.0, 6.0, 6.0, 9.0]);
    }

    #[test]
    fn avgpool_known() {
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 3., 5., 7.]);
        let (y, _) = avgpool(&x, 2);
        assert_eq!(y.data(), &[4.0]);
    }

    #[test]
    fn maxpool_overlapping() {
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32).collect());
        let (y, _) = maxpool(&x, 3, 2, 0);
        assert_eq!(y.data(), &[8.0]);
        let (y2, _) = maxpool(&x, 2, 1, 0);
        assert_eq!(y2.data(), &[4., 5., 7., 8.]);
    }

    #[test]
    fn maxpool_padded_stride2() {
        // 3x3 ramp, k=3 stride=2 pad=1 → 2x2 output; padded taps are
        // skipped, so each output is the max of the in-bounds window.
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32).collect());
        let (y, _) = maxpool(&x, 3, 2, 1);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[4.0, 5.0, 7.0, 8.0]);
        // negative inputs: skipping (not zero-filling) the pad is what
        // keeps an all-negative window from reporting 0.
        let xn = Tensor::new(&[1, 1, 2, 2], vec![-4., -3., -2., -1.]);
        let (yn, _) = maxpool(&xn, 2, 2, 1);
        assert_eq!(yn.shape(), &[1, 1, 2, 2]);
        assert_eq!(yn.data(), &[-4., -3., -2., -1.]);
    }

    #[test]
    fn grouped_conv_matches_per_group_dense() {
        // groups=2 conv == two independent dense convs on channel halves
        let mut v = 0.3f32;
        let mut next = || {
            v = (v * 1.7 + 0.13).fract();
            v - 0.5
        };
        let x = Tensor::new(&[1, 4, 5, 6], (0..120).map(|_| next()).collect());
        let w = Tensor::new(&[6, 2, 2, 3], (0..72).map(|_| next()).collect());
        let b = Tensor::new(&[6], (0..6).map(|_| next()).collect());
        let mut out = Vec::new();
        let (shape, counts) = conv2d_into(
            x.data(),
            x.shape(),
            w.data(),
            w.shape(),
            b.data(),
            1,
            1,
            0,
            2,
            &mut out,
        );
        assert_eq!(shape, [1, 6, 6, 4]);
        assert_eq!(counts.muls, 6 * 2 * 2 * 3 * 6 * 4);
        for g in 0..2 {
            let xg = Tensor::new(&[1, 2, 5, 6], x.data()[g * 60..(g + 1) * 60].to_vec());
            let wg = Tensor::new(&[3, 2, 2, 3], w.data()[g * 36..(g + 1) * 36].to_vec());
            let bg = Tensor::new(&[3], b.data()[g * 3..(g + 1) * 3].to_vec());
            let mut og = Vec::new();
            conv2d_into(
                xg.data(),
                xg.shape(),
                wg.data(),
                wg.shape(),
                bg.data(),
                1,
                1,
                0,
                1,
                &mut og,
            );
            assert_eq!(&out[g * 72..(g + 1) * 72], &og[..], "group {g}");
        }
    }

    #[test]
    fn dense_known() {
        let x = Tensor::new(&[1, 3], vec![1., 2., 3.]);
        let w = Tensor::new(&[2, 3], vec![1., 0., 0., 0., 1., 1.]);
        let b = Tensor::new(&[2], vec![0.5, -0.5]);
        let (y, c) = dense(&x, &w, &b);
        assert_eq!(y.data(), &[1.5, 4.5]);
        assert_eq!(c.muls, 6);
    }

    #[test]
    fn activations() {
        let mut t = Tensor::new(&[3], vec![-1.0, 0.0, 1.0]);
        let n = Activation::Relu.apply(&mut t);
        assert_eq!(n, 3);
        assert_eq!(t.data(), &[0.0, 0.0, 1.0]);
        let mut t2 = Tensor::new(&[1], vec![0.0]);
        Activation::Tanh.apply(&mut t2);
        assert_eq!(t2.data(), &[0.0]);
    }

    #[test]
    fn forward_into_matches_forward() {
        let w = Tensor::full(&[1, 1, 2, 2], 0.5);
        let b = Tensor::new(&[1], vec![0.25]);
        let layer = Layer::new(
            "c",
            LayerKind::Conv2d { weight: w, bias: b, stride: 1, pad_h: 0, pad_w: 0, groups: 1 },
            Activation::Tanh,
        );
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32 * 0.1).collect());
        let (want, want_counts) = layer.forward(&x);
        let mut buf = vec![9.0; 3]; // stale values must be fully overwritten
        let (shape, counts) = layer.forward_into(x.data(), x.shape(), &mut buf);
        assert_eq!(shape, want.shape());
        assert_eq!(&buf[..], want.data());
        assert_eq!(counts, want_counts);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., -1., 0., 1.]);
        let s = softmax_rows(&t);
        for bi in 0..2 {
            let sum: f32 = s.data()[bi * 3..(bi + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }
}
