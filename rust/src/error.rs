//! Typed library errors.
//!
//! Library surfaces (the coordinator intake and the accel engine) return
//! [`SubaccelError`] so callers can *match* on failure modes — retry on
//! [`SubaccelError::QueueFull`], reject on [`SubaccelError::BadShape`] —
//! instead of grepping strings. `anyhow` stays at the binary edge and in
//! the artifact-I/O paths where errors are environmental, not actionable;
//! `SubaccelError` implements [`std::error::Error`], so `?` converts it
//! into `anyhow::Error` at that edge for free.
//!
//! Hand-rolled (no `thiserror` in the offline vendor set).

use std::fmt;

/// Errors produced by the library surfaces of this crate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubaccelError {
    /// The coordinator's bounded intake queue is full (backpressure).
    /// Retriable: resubmit after a short wait.
    QueueFull,
    /// The coordinator pipeline has shut down; no further requests will
    /// be accepted. Not retriable.
    PipelineClosed,
    /// An input tensor's shape differs from what the pipeline was built
    /// for (e.g. a non-`(1,1,32,32)` image submitted to the LeNet-5
    /// coordinator).
    BadShape { expected: Vec<usize>, got: Vec<usize> },
    /// A conv input's per-patch length (`Cin·kh·kw`) does not match the
    /// pairing the layer was compiled with.
    KernelMismatch { expected_k: usize, got_k: usize },
    /// A configuration builder rejected an invalid field or combination.
    InvalidConfig { field: &'static str, reason: String },
}

impl fmt::Display for SubaccelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubaccelError::QueueFull => {
                write!(f, "queue full: backpressure rejection")
            }
            SubaccelError::PipelineClosed => {
                write!(f, "pipeline closed: coordinator has shut down")
            }
            SubaccelError::BadShape { expected, got } => {
                write!(f, "bad input shape: expected {expected:?}, got {got:?}")
            }
            SubaccelError::KernelMismatch { expected_k, got_k } => {
                write!(
                    f,
                    "input channels/kernel mismatch: pairing compiled for \
                     K={expected_k}, input yields K={got_k}"
                )
            }
            SubaccelError::InvalidConfig { field, reason } => {
                write!(f, "invalid config `{field}`: {reason}")
            }
        }
    }
}

impl std::error::Error for SubaccelError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failure_mode() {
        assert!(SubaccelError::QueueFull.to_string().contains("queue full"));
        let e = SubaccelError::BadShape { expected: vec![1, 1, 32, 32], got: vec![1, 1, 28, 28] };
        assert!(e.to_string().contains("[1, 1, 32, 32]"), "{e}");
        let e = SubaccelError::KernelMismatch { expected_k: 150, got_k: 75 };
        assert!(e.to_string().contains("150"), "{e}");
    }

    #[test]
    fn matchable_variants() {
        let e: SubaccelError = SubaccelError::QueueFull;
        assert!(matches!(e, SubaccelError::QueueFull));
        assert_eq!(SubaccelError::QueueFull, SubaccelError::QueueFull);
        assert_ne!(SubaccelError::QueueFull, SubaccelError::PipelineClosed);
    }

    #[test]
    fn converts_into_anyhow_at_the_edge() {
        fn edge() -> anyhow::Result<()> {
            Err(SubaccelError::QueueFull)?
        }
        let err = edge().unwrap_err();
        assert!(err.downcast_ref::<SubaccelError>().is_some());
        assert!(matches!(err.downcast_ref(), Some(SubaccelError::QueueFull)));
    }
}
