//! Parallel execution engine for paired convolution.
//!
//! Two ideas, both borrowed from how multiplier-less hardware actually
//! wins (TMA, arXiv:1909.04551; weight-sharing MAC units,
//! arXiv:1801.10219): a cache-friendly layout and wide parallelism over
//! cheap ops.
//!
//! * [`PackedPairing`] — a structure-of-arrays view of a
//!   [`LayerPairing`]: all filters' `(i1, i2, k)` triples and
//!   `(idx, w)` MAC taps live in five flat arrays with CSR-style
//!   per-filter offset tables. The hot loop walks contiguous slices
//!   instead of chasing a `Vec<FilterPairing>` of small heap blocks.
//! * [`ConvEngine`] — a persistent std-thread worker pool (the vendored
//!   set has no async runtime; this matches the coordinator's
//!   thread+channel design) that distributes im2col rows across cores
//!   through a work-stealing [`ChunkQueue`]: every engaged thread
//!   (workers and the caller alike) claims [`steal_chunk_rows`]-sized
//!   row chunks off one atomic cursor until the queue is dry, so
//!   tap-heavy layers with few rows no longer idle workers behind an
//!   even split. The engine owns reusable scratch buffers, so a
//!   steady-state [`ConvEngine::forward_packed_into`] call performs
//!   **zero heap allocation**. [`ConvEngine::forward_packed_slice_into`]
//!   is the same path on raw activation slices, for the whole-network
//!   plans in [`crate::exec`].
//! * **Tile blocking** — each shard walks its rows in tiles of `R` rows
//!   × all filters ([`compute_rows_tiled`]), with the filter loop on the
//!   outside: one filter's CSR tap slices (`pair_i1/pair_i2/pair_k`,
//!   `unp_idx/unp_w`) are streamed from memory once per *tile* instead
//!   of once per *row*. The tile's patches come from a streaming
//!   [`im2col_rows_into`] strip (`R·k_len` floats, sized to stay
//!   L1-resident by [`tile_rows_heuristic`]; override with
//!   `SUBACCEL_TILE_ROWS`, [`ConvEngine::with_tile_rows`], or — lowest
//!   override priority — a per-call autotuned tile from
//!   [`crate::accel::autotune`]) — the full patch matrix is never
//!   materialised.
//!
//! Numerics: every path — serial, caller shard, worker shard, any tile
//! size — computes each output element with exactly the same reduction
//! order (pair lane summed in table order, then MAC lane, then
//! `bias + pair + mac`), and Rust f32 arithmetic is strict — so results
//! are **bit-identical** across thread counts *and* tile sizes, and to
//! the untiled reference kernel [`ConvEngine::forward_packed_reference`]
//! (tiling only regroups independent output elements; see
//! ARCHITECTURE.md). Property-tested in `rust/tests/prop_engine.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

use super::preprocess::{FilterPairing, LayerPairing};
use crate::error::SubaccelError;
use crate::nn::OpCounts;
use crate::tensor::{im2col_rows_into, im2col_shape, im2col_slice_into, Tensor};

/// Spatial geometry of a conv layer (everything [`ConvEngine`] needs
/// beyond the pairing itself): kernel extent, stride, independent
/// row/column zero padding, and channel groups.
///
/// With `groups > 1` the input's channels split into `groups` equal
/// blocks and filter `c` reads only its block — the pairing's `k_len`
/// stays the *per-filter* flat length `Cin/groups · kh · kw`, while an
/// im2col patch row carries all `Cin · kh · kw` values; the kernels add
/// the filter's group base offset when gathering taps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeometry {
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad_h: usize,
    pub pad_w: usize,
    pub groups: usize,
}

impl ConvGeometry {
    /// Valid convolution, stride 1 (LeNet geometry).
    pub fn valid(kh: usize, kw: usize) -> Self {
        Self::symmetric(kh, kw, 1, 0)
    }

    /// Ungrouped convolution with symmetric padding (the historical
    /// `(stride, pad)` geometry every pre-grouped call site used).
    pub fn symmetric(kh: usize, kw: usize, stride: usize, pad: usize) -> Self {
        Self { kh, kw, stride, pad_h: pad, pad_w: pad, groups: 1 }
    }
}

/// Output geometry of one engine forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvOutShape {
    pub batch: usize,
    pub cout: usize,
    pub out_h: usize,
    pub out_w: usize,
}

impl ConvOutShape {
    pub fn dims(&self) -> [usize; 4] {
        [self.batch, self.cout, self.out_h, self.out_w]
    }
}

/// Structure-of-arrays layout of a whole layer's pairing.
///
/// Filter `c`'s subtractor triples are
/// `pair_i1/pair_i2/pair_k[pair_off[c] .. pair_off[c+1]]`, its MAC taps
/// `unp_idx/unp_w[unp_off[c] .. unp_off[c+1]]`. Built once at compile
/// time ([`PackedPairing::from_layer`]); round-trips losslessly
/// ([`PackedPairing::to_layer`]).
#[derive(Debug, Clone)]
pub struct PackedPairing {
    cout: usize,
    k_len: usize,
    shape: Vec<usize>,
    rounding: f32,
    pair_i1: Vec<u32>,
    pair_i2: Vec<u32>,
    pair_k: Vec<f32>,
    unp_idx: Vec<u32>,
    unp_w: Vec<f32>,
    /// `cout + 1` offsets into the pair arrays.
    pair_off: Vec<u32>,
    /// `cout + 1` offsets into the unpaired arrays.
    unp_off: Vec<u32>,
}

impl PackedPairing {
    /// Flatten a [`LayerPairing`] into the packed layout.
    pub fn from_layer(lp: &LayerPairing) -> Self {
        let cout = lp.filters.len();
        let n_pairs: usize = lp.filters.iter().map(|f| f.n_pairs()).sum();
        let n_unp: usize = lp.filters.iter().map(|f| f.n_unpaired()).sum();
        let mut p = Self {
            cout,
            k_len: lp.k_len,
            shape: lp.shape.clone(),
            rounding: lp.rounding,
            pair_i1: Vec::with_capacity(n_pairs),
            pair_i2: Vec::with_capacity(n_pairs),
            pair_k: Vec::with_capacity(n_pairs),
            unp_idx: Vec::with_capacity(n_unp),
            unp_w: Vec::with_capacity(n_unp),
            pair_off: Vec::with_capacity(cout + 1),
            unp_off: Vec::with_capacity(cout + 1),
        };
        p.pair_off.push(0);
        p.unp_off.push(0);
        for f in &lp.filters {
            p.pair_i1.extend_from_slice(&f.pair_i1);
            p.pair_i2.extend_from_slice(&f.pair_i2);
            p.pair_k.extend_from_slice(&f.pair_k);
            p.unp_idx.extend_from_slice(&f.unp_idx);
            p.unp_w.extend_from_slice(&f.unp_w);
            p.pair_off.push(p.pair_k.len() as u32);
            p.unp_off.push(p.unp_w.len() as u32);
        }
        // Bounds proof for the tiled kernel's unchecked gathers: every
        // tap index is `< k_len`, checked once here at pack time so
        // `compute_rows_tiled` can use `get_unchecked` on patch rows of
        // length `k_len` (the engine rejects any other patch length with
        // `KernelMismatch` before reaching the kernel).
        let k = p.k_len as u32;
        assert!(
            p.pair_i1.iter().chain(&p.pair_i2).chain(&p.unp_idx).all(|&i| i < k),
            "pairing tap index out of range (k_len {})",
            p.k_len
        );
        p
    }

    /// Reconstruct the per-filter representation (lossless inverse of
    /// [`PackedPairing::from_layer`]).
    pub fn to_layer(&self) -> LayerPairing {
        let filters = (0..self.cout)
            .map(|c| {
                let (i1, i2, k) = self.pairs(c);
                let (ui, uw) = self.unpaired(c);
                FilterPairing {
                    pair_i1: i1.to_vec(),
                    pair_i2: i2.to_vec(),
                    pair_k: k.to_vec(),
                    unp_idx: ui.to_vec(),
                    unp_w: uw.to_vec(),
                }
            })
            .collect();
        LayerPairing {
            filters,
            k_len: self.k_len,
            shape: self.shape.clone(),
            rounding: self.rounding,
        }
    }

    /// Filter `c`'s subtractor triples `(i1, i2, k)`.
    #[inline]
    pub fn pairs(&self, c: usize) -> (&[u32], &[u32], &[f32]) {
        let (a, b) = (self.pair_off[c] as usize, self.pair_off[c + 1] as usize);
        (&self.pair_i1[a..b], &self.pair_i2[a..b], &self.pair_k[a..b])
    }

    /// Filter `c`'s ordinary MAC taps `(idx, w)`.
    #[inline]
    pub fn unpaired(&self, c: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.unp_off[c] as usize, self.unp_off[c + 1] as usize);
        (&self.unp_idx[a..b], &self.unp_w[a..b])
    }

    pub fn cout(&self) -> usize {
        self.cout
    }

    /// Flattened filter length `Cin·kh·kw`.
    pub fn k_len(&self) -> usize {
        self.k_len
    }

    /// Original OIHW weight shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn total_pairs(&self) -> usize {
        self.pair_k.len()
    }

    pub fn total_unpaired(&self) -> usize {
        self.unp_w.len()
    }

    /// Total tap-table *entries* the kernel streams per im2col row: each
    /// pair contributes its two indices and one `k`, each unpaired tap
    /// its index and weight. [`tile_rows_heuristic`] uses this as the
    /// layer's tap-bandwidth measure.
    pub fn total_taps(&self) -> usize {
        3 * self.pair_k.len() + 2 * self.unp_w.len()
    }

    /// Rectangular zero-padded tap tables, row-major `(cout, pmax)` for
    /// the pair arrays and `(cout, umax)` for the MAC arrays — the wire
    /// layout the AOT-compiled PJRT paired artifact expects
    /// ([`crate::runtime`]). Indices widen to `i32` (XLA's gather index
    /// type); padding lanes are index 0 with weight 0.0, so they gather
    /// a real element and multiply it away.
    ///
    /// Errors with [`SubaccelError::InvalidConfig`] when any filter has
    /// more pairs than `pmax` or more unpaired taps than `umax`.
    pub fn padded_tables(&self, pmax: usize, umax: usize) -> Result<PaddedTables, SubaccelError> {
        let mut t = PaddedTables {
            pair_i1: vec![0; self.cout * pmax],
            pair_i2: vec![0; self.cout * pmax],
            pair_k: vec![0.0; self.cout * pmax],
            unp_idx: vec![0; self.cout * umax],
            unp_w: vec![0.0; self.cout * umax],
        };
        for c in 0..self.cout {
            let (i1, i2, k) = self.pairs(c);
            let (ui, uw) = self.unpaired(c);
            if i1.len() > pmax || ui.len() > umax {
                return Err(SubaccelError::InvalidConfig {
                    field: "padded_tables",
                    reason: format!(
                        "filter {c}: {} pairs / {} unpaired exceed table sizes ({pmax}, {umax})",
                        i1.len(),
                        ui.len()
                    ),
                });
            }
            for (j, (&a, (&b, &kv))) in i1.iter().zip(i2.iter().zip(k)).enumerate() {
                t.pair_i1[c * pmax + j] = a as i32;
                t.pair_i2[c * pmax + j] = b as i32;
                t.pair_k[c * pmax + j] = kv;
            }
            for (j, (&iu, &wv)) in ui.iter().zip(uw).enumerate() {
                t.unp_idx[c * umax + j] = iu as i32;
                t.unp_w[c * umax + j] = wv;
            }
        }
        Ok(t)
    }
}

/// Zero-padded rectangular tap tables produced by
/// [`PackedPairing::padded_tables`] — the single source of the PJRT
/// paired artifact's table literals.
#[derive(Debug, Clone, PartialEq)]
pub struct PaddedTables {
    /// `(cout, pmax)` row-major first-operand gather indices.
    pub pair_i1: Vec<i32>,
    /// `(cout, pmax)` row-major second-operand gather indices.
    pub pair_i2: Vec<i32>,
    /// `(cout, pmax)` row-major combined-pair weights.
    pub pair_k: Vec<f32>,
    /// `(cout, umax)` row-major MAC gather indices.
    pub unp_idx: Vec<i32>,
    /// `(cout, umax)` row-major MAC weights.
    pub unp_w: Vec<f32>,
}

/// Atomic-cursor chunk queue: one forward's im2col rows, handed out in
/// fixed-size chunks to whoever asks next. Every engaged thread — the
/// `threads − 1` pool workers *and* the calling thread — loops on
/// [`ChunkQueue::claim`] until the queue is dry, so a thread stuck on a
/// slow chunk (cache-cold region, noisy core) never strands rows that a
/// faster thread could take. This replaces the old even
/// `⌈rows/threads⌉` split, whose static assignment idled workers on
/// tap-heavy layers with few rows.
///
/// Guarantees (pinned by `rust/tests/steal_sched.rs`):
///
/// * every row `0 .. rows` is covered by **exactly one** claim — the
///   cursor is a single `fetch_add`, so two claimants can never receive
///   overlapping ranges;
/// * a claim is never empty — the last one is clamped to `rows`, and
///   claims past the end return `None` (the even split's empty-tail
///   remainder class is unrepresentable here);
/// * a claimant that panics mid-chunk loses only its own chunk: the
///   cursor has already moved past it, and the remaining chunks stay
///   claimable by the surviving threads (no lock to poison).
pub struct ChunkQueue {
    cursor: AtomicUsize,
    rows: usize,
    chunk: usize,
}

impl ChunkQueue {
    /// Queue over `rows` rows handed out `chunk` at a time
    /// (see [`steal_chunk_rows`] for how the engine sizes chunks).
    pub fn new(rows: usize, chunk: usize) -> Self {
        assert!(chunk >= 1, "chunk must be at least 1");
        Self { cursor: AtomicUsize::new(0), rows, chunk }
    }

    /// Claim the next chunk as a half-open row range `(start, end)`,
    /// or `None` once the queue is dry. Never returns an empty range.
    ///
    /// `Relaxed` suffices for uniqueness — `fetch_add` on one location
    /// is totally ordered regardless of memory order; the *results* the
    /// claimants write are published to the dispatcher by the done
    /// channel, not by this cursor.
    #[inline]
    pub fn claim(&self) -> Option<(usize, usize)> {
        let start = self.cursor.fetch_add(self.chunk, Ordering::Relaxed);
        if start >= self.rows {
            return None;
        }
        Some((start, (start + self.chunk).min(self.rows)))
    }

    /// Total rows the queue hands out.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Rows per claim (the last claim may be shorter, never empty).
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Number of claims a full drain performs.
    pub fn n_chunks(&self) -> usize {
        if self.rows == 0 {
            0
        } else {
            (self.rows + self.chunk - 1) / self.chunk
        }
    }
}

/// Chunk size for one forward's [`ChunkQueue`]: aim for ~4 claims per
/// engaged thread (enough granularity to rebalance around a slow thread
/// without hammering the shared cursor), snapped to whole row tiles so
/// in-chunk tiling stays full-depth — except when rows are scarce, where
/// sub-tile chunks keep every core fed (a 6-row layer on 8 threads hands
/// out 6 single-row chunks rather than one 6-row chunk).
pub fn steal_chunk_rows(rows: usize, tile: usize, threads: usize) -> usize {
    const CLAIMS_PER_THREAD: usize = 4;
    let denom = threads.max(1) * CLAIMS_PER_THREAD;
    let target = ((rows + denom - 1) / denom).max(1);
    let tile = tile.max(1);
    if target <= tile {
        target
    } else {
        let tiles_per_chunk = (target + tile - 1) / tile;
        tiles_per_chunk * tile
    }
}

/// One worker's view of a forward: a raw view of the caller's input plus
/// geometry (each worker streams its own im2col strips from the input —
/// patches are never pre-materialised), the shared [`ChunkQueue`], the
/// *whole* row-major output region, and the caller's pairing/bias. The
/// worker claims row chunks off the queue and writes only
/// `out[start·cout .. end·cout]` for each claim.
///
/// Sound because (a) the dispatching thread holds the engine lock and
/// blocks on the done channel until every engaged worker has drained the
/// queue and acknowledged, so every raw view outlives its use; and
/// (b) claims are disjoint by the queue's single-`fetch_add` contract,
/// so no two threads ever write the same output element (the caller's
/// own writes go through the same claim protocol).
struct Shard {
    x: *const f32,
    x_len: usize,
    shape: [usize; 4],
    geo: ConvGeometry,
    /// The forward's shared chunk queue (lives on the dispatcher's
    /// stack for the duration of the forward).
    queue: *const ChunkQueue,
    /// Base of the full `(rows, cout)` row-major output region.
    out: *mut f32,
    out_len: usize,
    cout: usize,
    packed: *const PackedPairing,
    bias: *const f32,
    bias_len: usize,
    /// Row tile size, fixed per forward so all claimants block identically.
    tile: usize,
}

// Raw pointers strip auto-Send; the dispatch protocol above restores the
// guarantee (disjoint claimed writes, caller outlives the shard).
unsafe impl Send for Shard {}

struct Pool {
    job_txs: Vec<Sender<Shard>>,
    done_rx: Receiver<()>,
}

struct Scratch {
    /// The calling thread's streaming im2col strip (workers own their
    /// own — see `worker_loop`). Grows to the largest `tile · k_len`
    /// seen, then steady state allocates nothing.
    strip: Vec<f32>,
    rowmajor: Vec<f32>,
}

struct Inner {
    scratch: Scratch,
    pool: Option<Pool>,
}

/// Multi-threaded paired-conv executor with persistent workers and
/// reusable scratch. Cheap to share (`Arc<ConvEngine>`); one engine per
/// coordinator replica is the intended granularity.
///
/// `Sync` by construction: all mutable state (scratch and the pool's
/// `mpsc` endpoints, which are `!Sync`) sits behind one internal mutex,
/// so concurrent `forward_*` calls serialize rather than race.
pub struct ConvEngine {
    threads: usize,
    /// Fixed row-tile override (`SUBACCEL_TILE_ROWS` env or
    /// [`ConvEngine::with_tile_rows`]); `None` → per-layer
    /// [`tile_rows_heuristic`].
    tile_rows: Option<usize>,
    inner: Mutex<Inner>,
    handles: Vec<JoinHandle<()>>,
}

impl ConvEngine {
    /// Build an engine running on `threads` OS threads total (the
    /// calling thread counts as one; `threads - 1` workers are spawned).
    ///
    /// Reads the `SUBACCEL_TILE_ROWS` override once here (construction,
    /// not the hot path); unset, empty, or unparsable values fall back
    /// to the per-layer tile heuristic.
    pub fn new(threads: usize) -> Result<Self, SubaccelError> {
        Self::build(threads, env_tile_rows())
    }

    /// [`ConvEngine::new`] with a fixed row-tile size for every layer,
    /// overriding the per-layer heuristic — for bench sweeps and the
    /// tiled-vs-reference bit-identity tests. Equivalent to running with
    /// `SUBACCEL_TILE_ROWS=<tile_rows>`.
    pub fn with_tile_rows(threads: usize, tile_rows: usize) -> Result<Self, SubaccelError> {
        if tile_rows == 0 {
            return Err(SubaccelError::InvalidConfig {
                field: "tile_rows",
                reason: "row tile must be at least 1".into(),
            });
        }
        Self::build(threads, Some(tile_rows))
    }

    fn build(threads: usize, tile_rows: Option<usize>) -> Result<Self, SubaccelError> {
        if threads == 0 {
            return Err(SubaccelError::InvalidConfig {
                field: "threads",
                reason: "engine needs at least one thread".into(),
            });
        }
        let scratch = Scratch { strip: Vec::new(), rowmajor: Vec::new() };
        let (pool, handles) = if threads == 1 {
            (None, Vec::new())
        } else {
            let (done_tx, done_rx) = channel();
            let mut job_txs = Vec::with_capacity(threads - 1);
            let mut handles = Vec::with_capacity(threads - 1);
            for i in 0..threads - 1 {
                let (tx, rx) = channel::<Shard>();
                let done = done_tx.clone();
                let h = std::thread::Builder::new()
                    .name(format!("conv-engine-{i}"))
                    .spawn(move || worker_loop(rx, done))
                    .map_err(|e| SubaccelError::InvalidConfig {
                        field: "threads",
                        reason: format!("failed to spawn worker: {e}"),
                    })?;
                job_txs.push(tx);
                handles.push(h);
            }
            (Some(Pool { job_txs, done_rx }), handles)
        };
        Ok(Self { threads, tile_rows, inner: Mutex::new(Inner { scratch, pool }), handles })
    }

    /// Single-threaded engine (no workers; runs inline on the caller).
    pub fn serial() -> Self {
        Self::new(1).expect("1 thread is always valid")
    }

    /// Number of OS threads this engine computes on.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The fixed row-tile override, if any (`None` → per-layer
    /// heuristic).
    pub fn tile_rows(&self) -> Option<usize> {
        self.tile_rows
    }

    /// Detected host parallelism (≥ 1), for `--threads 0`-style auto
    /// configuration.
    pub fn host_threads() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Run a paired conv layer, allocating the output tensor.
    pub fn forward_packed(
        &self,
        packed: &PackedPairing,
        bias: &Tensor,
        geo: ConvGeometry,
        x: &Tensor,
    ) -> Result<(Tensor, OpCounts), SubaccelError> {
        let mut buf = Vec::new();
        let (os, counts) = self.forward_packed_into(packed, bias.data(), geo, x, &mut buf)?;
        Ok((Tensor::new(&os.dims(), buf), counts))
    }

    /// Run a paired conv layer into a caller-owned buffer (resized and
    /// fully overwritten). With a warm buffer this path performs zero
    /// heap allocation: the streaming im2col strip and the row-major
    /// intermediate live in engine scratch reused across calls (workers
    /// keep their own persistent strips).
    ///
    /// Errors with [`SubaccelError::KernelMismatch`] when the input's
    /// per-patch length differs from what the pairing was compiled for;
    /// non-NCHW inputs and bias-length mismatches are programming errors
    /// and panic (matching the crate's assert conventions).
    pub fn forward_packed_into(
        &self,
        packed: &PackedPairing,
        bias: &[f32],
        geo: ConvGeometry,
        x: &Tensor,
        out: &mut Vec<f32>,
    ) -> Result<(ConvOutShape, OpCounts), SubaccelError> {
        self.forward_packed_slice_into(packed, bias, geo, x.data(), x.shape(), out)
    }

    /// [`ConvEngine::forward_packed_into`] on a raw NCHW activation
    /// slice — the [`crate::exec`] executor's entry point. Whole-network
    /// plans keep activations in reusable ping-pong scratch rather than
    /// `Tensor`s, so no tensor handle (whose shape vector would
    /// allocate) exists on the steady-state path.
    pub fn forward_packed_slice_into(
        &self,
        packed: &PackedPairing,
        bias: &[f32],
        geo: ConvGeometry,
        xd: &[f32],
        xshape: &[usize],
        out: &mut Vec<f32>,
    ) -> Result<(ConvOutShape, OpCounts), SubaccelError> {
        self.forward_packed_tiled_slice_into(packed, bias, geo, xd, xshape, None, out)
    }

    /// [`ConvEngine::forward_packed_slice_into`] with a per-call row-tile
    /// request — the entry point for autotuned execution plans
    /// ([`crate::accel::autotune`], [`crate::exec`]) and bench sweeps.
    ///
    /// Tile precedence, highest first: `SUBACCEL_TILE_ROWS` /
    /// [`ConvEngine::with_tile_rows`] (both land in the engine-wide
    /// override, which this call does **not** bypass), then `tile_rows`
    /// here, then [`tile_rows_heuristic`]. `Some(0)` is a typed
    /// [`SubaccelError::InvalidConfig`], mirroring the constructor.
    ///
    /// The tile only regroups independent output elements, so any value
    /// is bit-identical to any other (`rust/tests/prop_autotune.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_packed_tiled_slice_into(
        &self,
        packed: &PackedPairing,
        bias: &[f32],
        geo: ConvGeometry,
        xd: &[f32],
        xshape: &[usize],
        tile_rows: Option<usize>,
        out: &mut Vec<f32>,
    ) -> Result<(ConvOutShape, OpCounts), SubaccelError> {
        assert_eq!(bias.len(), packed.cout, "bias length != Cout");
        if tile_rows == Some(0) {
            return Err(SubaccelError::InvalidConfig {
                field: "tile_rows",
                reason: "row tile must be at least 1".into(),
            });
        }
        check_geo(packed, geo)?;
        let s = im2col_shape(xshape, geo.kh, geo.kw, geo.stride, geo.pad_h, geo.pad_w);
        if s.k != geo.groups * packed.k_len {
            return Err(SubaccelError::KernelMismatch {
                expected_k: geo.groups * packed.k_len,
                got_k: s.k,
            });
        }
        let xs = [xshape[0], xshape[1], xshape[2], xshape[3]];
        debug_assert_eq!(xd.len(), xs.iter().product::<usize>(), "data length vs shape {xshape:?}");
        let (rows, cout) = (s.rows, packed.cout);
        let tile = self
            .tile_rows
            .or(tile_rows)
            .unwrap_or_else(|| tile_rows_heuristic(packed.k_len, cout, packed.total_taps()));

        // Poison recovery: the guarded state is pure scratch, resized and
        // fully overwritten below before any read — a panic mid-forward
        // on another thread leaves nothing a later call could observe, so
        // one wedged request must not poison every subsequent one.
        let inner = &mut *self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let Inner { scratch, pool } = inner;
        scratch.rowmajor.resize(rows * cout, 0.0);

        match pool {
            None => compute_shard(
                xd,
                &xs,
                geo,
                0,
                packed,
                bias,
                tile,
                &mut scratch.strip,
                &mut scratch.rowmajor[..],
            ),
            Some(pool) => {
                // Work-stealing dispatch: one shared atomic-cursor queue
                // of row chunks; workers and the calling thread all claim
                // from it until dry, so a slow thread never strands rows.
                let chunk = steal_chunk_rows(rows, tile, self.threads);
                let queue = ChunkQueue::new(rows, chunk);
                let out_len = rows * cout;
                let out_ptr = scratch.rowmajor.as_mut_ptr();

                // Engage only as many workers as there are chunks beyond
                // the caller's first claim — idle workers see no traffic.
                let engaged = pool.job_txs.len().min(queue.n_chunks().saturating_sub(1));
                for tx in &pool.job_txs[..engaged] {
                    let shard = Shard {
                        x: xd.as_ptr(),
                        x_len: xd.len(),
                        shape: xs,
                        geo,
                        queue: &queue as *const ChunkQueue,
                        out: out_ptr,
                        out_len,
                        cout,
                        packed: packed as *const PackedPairing,
                        bias: bias.as_ptr(),
                        bias_len: bias.len(),
                        tile,
                    };
                    tx.send(shard).expect("conv-engine worker died");
                }
                // The caller claims through the same protocol; all writes
                // to `out_ptr` (here and in workers) derive from this one
                // pointer over disjoint claimed ranges.
                while let Some((r0, r1)) = queue.claim() {
                    // Safety: claims are disjoint and in-bounds
                    // (`r1 <= rows`), so this view never overlaps a
                    // worker's.
                    let o = unsafe {
                        std::slice::from_raw_parts_mut(out_ptr.add(r0 * cout), (r1 - r0) * cout)
                    };
                    compute_shard(xd, &xs, geo, r0, packed, bias, tile, &mut scratch.strip, o);
                }
                // Blocks until every engaged worker has drained the queue
                // and acknowledged: the queue (on this stack frame) and
                // the input/output views outlive all worker access, and
                // the channel recv publishes the workers' writes.
                for _ in 0..engaged {
                    pool.done_rx.recv().expect("conv-engine worker died");
                }
            }
        }

        out.resize(rows * cout, 0.0);
        rowmajor_to_nchw(&scratch.rowmajor, s.batch, cout, s.out_h, s.out_w, out);

        let counts = OpCounts::paired_layer(
            packed.total_pairs() as u64,
            packed.total_unpaired() as u64,
            rows as u64,
            (rows * cout) as u64,
        );
        Ok((ConvOutShape { batch: s.batch, cout, out_h: s.out_h, out_w: s.out_w }, counts))
    }

    /// Untiled reference path: full-matrix im2col followed by the
    /// row-major [`compute_rows`] kernel, allocating everything fresh.
    /// This is the pre-tiling engine semantics, kept as the oracle the
    /// tiled path must match bit-for-bit (`rust/tests/prop_engine.rs`)
    /// and as the baseline the `conv_hotpath` bench compares against.
    pub fn forward_packed_reference(
        packed: &PackedPairing,
        bias: &Tensor,
        geo: ConvGeometry,
        x: &Tensor,
    ) -> Result<(Tensor, OpCounts), SubaccelError> {
        assert_eq!(bias.len(), packed.cout, "bias length != Cout");
        check_geo(packed, geo)?;
        let mut patches = Vec::new();
        let s = im2col_slice_into(
            x.data(),
            x.shape(),
            geo.kh,
            geo.kw,
            geo.stride,
            geo.pad_h,
            geo.pad_w,
            &mut patches,
        );
        if s.k != geo.groups * packed.k_len {
            return Err(SubaccelError::KernelMismatch {
                expected_k: geo.groups * packed.k_len,
                got_k: s.k,
            });
        }
        let (rows, cout) = (s.rows, packed.cout);
        let mut rowmajor = vec![0.0; rows * cout];
        compute_rows(&patches, s.k, geo.groups, packed, bias.data(), &mut rowmajor);
        let mut out = vec![0.0; rows * cout];
        rowmajor_to_nchw(&rowmajor, s.batch, cout, s.out_h, s.out_w, &mut out);
        let counts = OpCounts::paired_layer(
            packed.total_pairs() as u64,
            packed.total_unpaired() as u64,
            rows as u64,
            (rows * cout) as u64,
        );
        Ok((Tensor::new(&[s.batch, cout, s.out_h, s.out_w], out), counts))
    }
}

/// Geometry/pairing agreement checks shared by both engine entry points
/// (run before im2col, whose shape function asserts on `stride == 0`).
/// The patch-length check (`Cin·kh·kw == groups · k_len`) happens after
/// the input shape is known.
fn check_geo(packed: &PackedPairing, geo: ConvGeometry) -> Result<(), SubaccelError> {
    if geo.stride == 0 {
        return Err(SubaccelError::InvalidConfig {
            field: "stride",
            reason: "conv stride must be at least 1".into(),
        });
    }
    if geo.groups == 0 {
        return Err(SubaccelError::InvalidConfig {
            field: "groups",
            reason: "conv groups must be at least 1".into(),
        });
    }
    if packed.cout % geo.groups != 0 {
        return Err(SubaccelError::InvalidConfig {
            field: "groups",
            reason: format!(
                "{} output channels not divisible into {} groups",
                packed.cout, geo.groups
            ),
        });
    }
    Ok(())
}

/// Transpose the engine's `(rows, Cout)` row-major intermediate into the
/// NCHW output layout, rows ordered `(b, oy, ox)`.
fn rowmajor_to_nchw(rowmajor: &[f32], b: usize, cout: usize, oh: usize, ow: usize, out: &mut [f32]) {
    for bi in 0..b {
        for y in 0..oh {
            for xw in 0..ow {
                let r = (bi * oh + y) * ow + xw;
                for c in 0..cout {
                    out[((bi * cout + c) * oh + y) * ow + xw] = rowmajor[r * cout + c];
                }
            }
        }
    }
}

impl Drop for ConvEngine {
    fn drop(&mut self) {
        // Dropping the senders ends each worker's recv loop; recover from
        // poison so a panicked forward doesn't leak the worker threads.
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).pool = None;
        for h in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Receiver<Shard>, done: Sender<()>) {
    // Per-worker streaming im2col strip, reused across jobs: it grows to
    // the largest `tile · k_len` seen, then steady-state shards allocate
    // nothing (pinned by rust/tests/alloc_plan.rs for the serial path;
    // the worker path follows the same discipline).
    let mut strip: Vec<f32> = Vec::new();
    while let Ok(shard) = rx.recv() {
        // Safety: the dispatcher holds the engine lock and blocks until
        // our done token arrives, so these views (input, bias, pairing,
        // the queue on the dispatcher's stack, the output base) outlive
        // this block; each claimed row range is exclusively ours by the
        // queue's single-`fetch_add` contract, so the per-claim output
        // view never overlaps another thread's.
        unsafe {
            let x = std::slice::from_raw_parts(shard.x, shard.x_len);
            let bias = std::slice::from_raw_parts(shard.bias, shard.bias_len);
            let queue = &*shard.queue;
            while let Some((r0, r1)) = queue.claim() {
                debug_assert!(r1 * shard.cout <= shard.out_len);
                let out = std::slice::from_raw_parts_mut(
                    shard.out.add(r0 * shard.cout),
                    (r1 - r0) * shard.cout,
                );
                compute_shard(
                    x,
                    &shard.shape,
                    shard.geo,
                    r0,
                    &*shard.packed,
                    bias,
                    shard.tile,
                    &mut strip,
                    out,
                );
            }
        }
        if done.send(()).is_err() {
            break;
        }
    }
}

/// Run one contiguous row range `row0 .. row0 + out.len() / cout` of a
/// layer: stream `tile`-row im2col strips from the input into `strip`
/// and apply the tiled microkernel to each. Every path through the
/// engine — serial, caller shard, worker shard — runs exactly this code,
/// and each output element's reduction order is fixed inside
/// [`compute_rows_tiled`], which is what makes thread counts *and* tile
/// sizes bit-identical (tiling only regroups independent outputs).
#[allow(clippy::too_many_arguments)]
fn compute_shard(
    xd: &[f32],
    shape: &[usize; 4],
    geo: ConvGeometry,
    row0: usize,
    packed: &PackedPairing,
    bias: &[f32],
    tile: usize,
    strip: &mut Vec<f32>,
    out: &mut [f32],
) {
    // A strip row carries the full patch (`Cin·kh·kw` floats); with
    // groups each filter reads only its `k_len`-float block of it.
    let (k, cout) = (geo.groups * packed.k_len, packed.cout);
    let rows = out.len() / cout;
    let mut r = 0;
    while r < rows {
        let t = tile.min(rows - r);
        im2col_rows_into(
            xd,
            shape,
            geo.kh,
            geo.kw,
            geo.stride,
            geo.pad_h,
            geo.pad_w,
            row0 + r,
            t,
            strip,
        );
        compute_rows_tiled(
            &strip[..t * k],
            k,
            geo.groups,
            packed,
            bias,
            &mut out[r * cout..(r + t) * cout],
        );
        r += t;
    }
}

/// The untiled reference kernel: paired conv over a contiguous block of
/// im2col rows, rows outer / filters inner. Each output element is
/// `bias[c] + Σ k·(I1 − I2) + Σ w·I` with both lanes summed in table
/// order — [`compute_rows_tiled`] reproduces exactly this per-element
/// reduction, so the two kernels are bit-identical. The zip/sum shapes
/// mirror the original `SubConv2d` hot loop, preserving its numerics.
///
/// `k` is the full patch-row length `Cin·kh·kw` = `groups · k_len`;
/// filter `c` gathers from its group's `k_len`-float block of the patch
/// (a pure base-offset shift, so grouping never perturbs the per-element
/// reduction order).
fn compute_rows(
    patches: &[f32],
    k: usize,
    groups: usize,
    packed: &PackedPairing,
    bias: &[f32],
    out: &mut [f32],
) {
    let cout = packed.cout;
    let cpg = cout / groups;
    let rows = out.len() / cout;
    for r in 0..rows {
        let full = &patches[r * k..(r + 1) * k];
        for c in 0..cout {
            let base = (c / cpg) * packed.k_len;
            let patch = &full[base..base + packed.k_len];
            // subtractor lane: k·(I1 − I2) per combined pair
            let (i1, i2, kk) = packed.pairs(c);
            let pair_acc: f32 = i1
                .iter()
                .zip(i2)
                .zip(kk)
                .map(|((&a, &b), &kv)| kv * (patch[a as usize] - patch[b as usize]))
                .sum();
            // ordinary MAC lane
            let (ui, uw) = packed.unpaired(c);
            let mac_acc: f32 =
                ui.iter().zip(uw).map(|(&iu, &wv)| wv * patch[iu as usize]).sum();
            out[r * cout + c] = bias[c] + pair_acc + mac_acc;
        }
    }
}

/// The tile-blocked microkernel: same math as [`compute_rows`], loop
/// nest interchanged to filters outer / rows inner, so each filter's CSR
/// tap slices (and its bias) are loaded **once per tile** instead of
/// once per row — on tap-heavy layers that turns a bandwidth-bound loop
/// into an arithmetic-bound one. `patches` is one streaming strip of
/// `out.len() / cout` rows.
///
/// Bit-identity: the expression computing `out[r·cout + c]` — pair lane
/// summed in table order, then MAC lane, then `bias + pair + mac` — is
/// token-for-token the reference kernel's; only the order independent
/// output elements are *visited* in changes, and strict f32 makes each
/// element's value a function of its own reduction order alone.
///
/// Safety of the unchecked gathers: every index in the tap tables is
/// `< k_len` (asserted once in [`PackedPairing::from_layer`]) and every
/// `patch` view here is the filter's group block, exactly `k_len` floats
/// of a `k == groups · k_len`-float strip row (the engine rejects
/// mismatched inputs with [`SubaccelError::KernelMismatch`] before
/// dispatch, so the safe block slice below never truncates);
/// `debug_assert!` restates the proof in debug builds.
fn compute_rows_tiled(
    patches: &[f32],
    k: usize,
    groups: usize,
    packed: &PackedPairing,
    bias: &[f32],
    out: &mut [f32],
) {
    let cout = packed.cout;
    let cpg = cout / groups;
    let rows = out.len() / cout;
    debug_assert_eq!(k, groups * packed.k_len);
    debug_assert!(patches.len() >= rows * k);
    for c in 0..cout {
        let (i1, i2, kk) = packed.pairs(c);
        let (ui, uw) = packed.unpaired(c);
        let bc = bias[c];
        let base = (c / cpg) * packed.k_len;
        for r in 0..rows {
            let patch = &patches[r * k + base..r * k + base + packed.k_len];
            // subtractor lane: k·(I1 − I2) per combined pair
            let pair_acc: f32 = i1
                .iter()
                .zip(i2)
                .zip(kk)
                .map(|((&a, &b), &kv)| {
                    debug_assert!((a as usize) < patch.len() && (b as usize) < patch.len());
                    unsafe {
                        kv * (*patch.get_unchecked(a as usize) - *patch.get_unchecked(b as usize))
                    }
                })
                .sum();
            // ordinary MAC lane
            let mac_acc: f32 = ui
                .iter()
                .zip(uw)
                .map(|(&iu, &wv)| {
                    debug_assert!((iu as usize) < patch.len());
                    unsafe { wv * *patch.get_unchecked(iu as usize) }
                })
                .sum();
            out[r * cout + c] = bc + pair_acc + mac_acc;
        }
    }
}

/// Per-layer row-tile heuristic, balancing two pressures:
///
/// * the strip (`R · k_len` floats) must stay L1-resident next to the
///   current filter's tap slices — bound `R` by a ~24 KiB strip budget;
/// * tap reuse only pays in proportion to tap-table size: layers whose
///   per-filter tables already fit in a few cache lines (LeNet C1) gain
///   nothing from deep tiles, while tap-heavy layers (AlexNet conv2-5,
///   ~`avg_taps · 8` bytes per filter re-streamed per row before this
///   change) want tiles deep enough to amortise the whole table walk.
///
/// `total_taps` is [`PackedPairing::total_taps`]. Always returns ≥ 1.
pub fn tile_rows_heuristic(k_len: usize, cout: usize, total_taps: usize) -> usize {
    const STRIP_BUDGET_FLOATS: usize = 6 * 1024; // 24 KiB of L1 for the strip
    let by_l1 = (STRIP_BUDGET_FLOATS / k_len.max(1)).max(1);
    let avg_tap_bytes = 8 * total_taps / cout.max(1);
    let by_reuse = if avg_tap_bytes >= 4096 { 64 } else { 16 };
    by_l1.min(by_reuse)
}

/// Parse a `SUBACCEL_TILE_ROWS` value: `Ok(Some(n))` for a positive
/// integer, `Ok(None)` for empty/whitespace (treated as unset), and
/// `Err(reason)` for anything else — zero included, since a zero tile
/// can never be honoured and silently falling back to the heuristic
/// would hide the typo. Split out from [`env_tile_rows`] so both paths
/// are unit-testable without touching process environment.
fn parse_tile_rows(raw: &str) -> Result<Option<usize>, String> {
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Ok(None);
    }
    match trimmed.parse::<usize>() {
        Ok(0) => Err(format!("SUBACCEL_TILE_ROWS={raw:?}: row tile must be at least 1")),
        Ok(n) => Ok(Some(n)),
        Err(e) => Err(format!("SUBACCEL_TILE_ROWS={raw:?}: not a positive integer ({e})")),
    }
}

/// `SUBACCEL_TILE_ROWS` override, read once at engine construction.
/// Unset or empty means "use the heuristic"; a malformed or zero value
/// also falls back, but *loudly* — a warning on stderr instead of the
/// silent swallow that used to make a typo'd override indistinguishable
/// from no override.
fn env_tile_rows() -> Option<usize> {
    let raw = std::env::var("SUBACCEL_TILE_ROWS").ok()?;
    match parse_tile_rows(&raw) {
        Ok(tile) => tile,
        Err(reason) => {
            eprintln!("warning: ignoring tile override, falling back to heuristic: {reason}");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect())
    }

    #[test]
    fn zero_threads_is_a_typed_config_error() {
        match ConvEngine::new(0) {
            Err(SubaccelError::InvalidConfig { field, .. }) => assert_eq!(field, "threads"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn packed_offsets_are_consistent() {
        let mut rng = Rng::seed_from_u64(21);
        let w = rand_t(&mut rng, &[5, 3, 4, 4]);
        let lp = LayerPairing::from_weights(&w, 0.1);
        let p = PackedPairing::from_layer(&lp);
        assert_eq!(p.cout(), 5);
        assert_eq!(p.k_len(), 48);
        assert_eq!(p.total_pairs(), lp.total_pairs());
        for (c, f) in lp.filters.iter().enumerate() {
            let (i1, i2, k) = p.pairs(c);
            assert_eq!(i1, &f.pair_i1[..]);
            assert_eq!(i2, &f.pair_i2[..]);
            assert_eq!(k, &f.pair_k[..]);
            let (ui, uw) = p.unpaired(c);
            assert_eq!(ui, &f.unp_idx[..]);
            assert_eq!(uw, &f.unp_w[..]);
        }
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        let mut rng = Rng::seed_from_u64(7);
        let x = rand_t(&mut rng, &[2, 3, 11, 11]);
        let w = rand_t(&mut rng, &[6, 3, 3, 3]);
        let b = rand_t(&mut rng, &[6]);
        let lp = LayerPairing::from_weights(&w, 0.08);
        let p = PackedPairing::from_layer(&lp);
        let geo = ConvGeometry::valid(3, 3);

        let serial = ConvEngine::serial();
        let (want, want_counts) = serial.forward_packed(&p, &b, geo, &x).unwrap();
        for threads in 2..=4 {
            let eng = ConvEngine::new(threads).unwrap();
            let (got, counts) = eng.forward_packed(&p, &b, geo, &x).unwrap();
            assert_eq!(got.shape(), want.shape());
            assert_eq!(got.data(), want.data(), "{threads} threads diverged");
            assert_eq!(counts, want_counts);
        }
    }

    #[test]
    fn strided_padded_geometry_runs() {
        let mut rng = Rng::seed_from_u64(13);
        let x = rand_t(&mut rng, &[1, 3, 16, 16]);
        let w = rand_t(&mut rng, &[4, 3, 5, 5]);
        let b = rand_t(&mut rng, &[4]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.05));
        let eng = ConvEngine::new(3).unwrap();
        let geo = ConvGeometry::symmetric(5, 5, 2, 2);
        let (y, _) = eng.forward_packed(&p, &b, geo, &x).unwrap();
        assert_eq!(y.shape(), &[1, 4, 8, 8]);
        // matches the serial engine bit-for-bit on the same geometry
        let (y1, _) = ConvEngine::serial().forward_packed(&p, &b, geo, &x).unwrap();
        assert_eq!(y.data(), y1.data());
    }

    #[test]
    fn kernel_mismatch_is_typed() {
        let mut rng = Rng::seed_from_u64(3);
        let w = rand_t(&mut rng, &[2, 2, 3, 3]);
        let b = Tensor::zeros(&[2]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.0));
        let x = rand_t(&mut rng, &[1, 3, 8, 8]); // 3 channels ≠ 2
        let err = ConvEngine::serial()
            .forward_packed(&p, &b, ConvGeometry::valid(3, 3), &x)
            .unwrap_err();
        assert_eq!(err, SubaccelError::KernelMismatch { expected_k: 18, got_k: 27 });
    }

    #[test]
    fn zero_tile_rows_is_a_typed_config_error() {
        match ConvEngine::with_tile_rows(1, 0) {
            Err(SubaccelError::InvalidConfig { field, .. }) => assert_eq!(field, "tile_rows"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn tile_sizes_are_bit_identical_to_reference() {
        let mut rng = Rng::seed_from_u64(99);
        let x = rand_t(&mut rng, &[2, 3, 12, 12]);
        let w = rand_t(&mut rng, &[5, 3, 3, 3]);
        let b = rand_t(&mut rng, &[5]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.05));
        let geo = ConvGeometry::symmetric(3, 3, 1, 1);
        let (want, want_counts) = ConvEngine::forward_packed_reference(&p, &b, geo, &x).unwrap();
        // rows = 2·12·12 = 288, so 1000 exercises the tile > rows case
        for tile in [1usize, 2, 7, 64, 1000] {
            for threads in [1usize, 3] {
                let eng = ConvEngine::with_tile_rows(threads, tile).unwrap();
                let (got, counts) = eng.forward_packed(&p, &b, geo, &x).unwrap();
                assert_eq!(got.shape(), want.shape());
                assert_eq!(got.data(), want.data(), "tile {tile} t={threads} diverged");
                assert_eq!(counts, want_counts);
            }
        }
    }

    #[test]
    fn tile_heuristic_is_bounded() {
        // strip stays within the L1 budget and the tile is never zero
        for (k_len, cout, taps) in
            [(1, 1, 0), (25, 6, 60), (150, 16, 2000), (2400, 256, 600_000), (100_000, 4, 10)]
        {
            let t = tile_rows_heuristic(k_len, cout, taps);
            assert!(t >= 1, "tile must be >= 1");
            assert!(
                t == 1 || t * k_len <= 64 * 1024,
                "strip {t}x{k_len} floats blows the cache budget"
            );
        }
        // at equal k_len, a tap-heavy layer gets a deeper tile than a
        // tap-light one (reuse only pays when the tables are big)
        assert!(tile_rows_heuristic(150, 16, 100_000) > tile_rows_heuristic(150, 16, 60));
    }

    #[test]
    fn padded_tables_match_filter_layout() {
        let mut rng = Rng::seed_from_u64(47);
        let w = rand_t(&mut rng, &[4, 2, 3, 3]);
        let lp = LayerPairing::from_weights(&w, 0.1);
        let p = PackedPairing::from_layer(&lp);
        let pmax = lp.filters.iter().map(|f| f.n_pairs()).max().unwrap() + 1;
        let umax = lp.filters.iter().map(|f| f.n_unpaired()).max().unwrap() + 2;
        let t = p.padded_tables(pmax, umax).unwrap();
        assert_eq!(t.pair_i1.len(), 4 * pmax);
        assert_eq!(t.unp_w.len(), 4 * umax);
        for (c, f) in lp.filters.iter().enumerate() {
            for (j, &a) in f.pair_i1.iter().enumerate() {
                assert_eq!(t.pair_i1[c * pmax + j], a as i32);
                assert_eq!(t.pair_i2[c * pmax + j], f.pair_i2[j] as i32);
                assert_eq!(t.pair_k[c * pmax + j], f.pair_k[j]);
            }
            // padding lanes: index 0, weight 0.0
            for j in f.n_pairs()..pmax {
                assert_eq!(t.pair_i1[c * pmax + j], 0);
                assert_eq!(t.pair_k[c * pmax + j], 0.0);
            }
            for (j, &iu) in f.unp_idx.iter().enumerate() {
                assert_eq!(t.unp_idx[c * umax + j], iu as i32);
                assert_eq!(t.unp_w[c * umax + j], f.unp_w[j]);
            }
            for j in f.n_unpaired()..umax {
                assert_eq!(t.unp_w[c * umax + j], 0.0);
            }
        }
        // undersized tables are a typed error, not silent truncation
        match p.padded_tables(0, umax) {
            Err(SubaccelError::InvalidConfig { field, .. }) => {
                assert_eq!(field, "padded_tables");
            }
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn reused_buffer_is_fully_overwritten() {
        let mut rng = Rng::seed_from_u64(31);
        let w = rand_t(&mut rng, &[3, 2, 3, 3]);
        let b = rand_t(&mut rng, &[3]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.1));
        let eng = ConvEngine::new(2).unwrap();
        let geo = ConvGeometry::valid(3, 3);
        let big = rand_t(&mut rng, &[2, 2, 10, 10]);
        let small = rand_t(&mut rng, &[1, 2, 5, 5]);
        let mut buf = Vec::new();
        eng.forward_packed_into(&p, b.data(), geo, &big, &mut buf).unwrap();
        let (os, _) = eng.forward_packed_into(&p, b.data(), geo, &small, &mut buf).unwrap();
        assert_eq!(buf.len(), os.dims().iter().product::<usize>());
        let (fresh, _) = eng.forward_packed(&p, &b, geo, &small).unwrap();
        assert_eq!(&buf[..], fresh.data());
    }

    #[test]
    fn grouped_conv_equals_per_group_ungrouped_convs() {
        // groups=2: filters 0..2 read channels 0..2, filters 2..4 read
        // channels 2..4. Running each group as an independent ungrouped
        // conv must reproduce the grouped forward bit-for-bit (the group
        // base offset only shifts where taps gather from, never the
        // per-element reduction order).
        let mut rng = Rng::seed_from_u64(61);
        let w = rand_t(&mut rng, &[4, 2, 3, 3]);
        let b = rand_t(&mut rng, &[4]);
        let x = rand_t(&mut rng, &[1, 4, 8, 8]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.1));
        let geo = ConvGeometry { kh: 3, kw: 3, stride: 1, pad_h: 1, pad_w: 1, groups: 2 };
        for threads in [1usize, 3] {
            let eng = ConvEngine::new(threads).unwrap();
            let (got, _) = eng.forward_packed(&p, &b, geo, &x).unwrap();
            assert_eq!(got.shape(), &[1, 4, 8, 8]);
            let ungrouped = ConvGeometry::symmetric(3, 3, 1, 1);
            let mut want = Vec::new();
            for g in 0..2usize {
                let wg = Tensor::new(&[2, 2, 3, 3], w.data()[g * 36..(g + 1) * 36].to_vec());
                let bg = Tensor::new(&[2], b.data()[g * 2..(g + 1) * 2].to_vec());
                let xg = Tensor::new(&[1, 2, 8, 8], x.data()[g * 128..(g + 1) * 128].to_vec());
                let pg = PackedPairing::from_layer(&LayerPairing::from_weights(&wg, 0.1));
                let (yg, _) = eng.forward_packed(&pg, &bg, ungrouped, &xg).unwrap();
                want.extend_from_slice(yg.data());
            }
            assert_eq!(got.data(), &want[..], "t={threads}: grouped path diverged");
        }
    }

    #[test]
    fn grouped_nonsquare_asym_tiled_matches_reference() {
        // the full generalized geometry at once — groups, kh≠kw,
        // pad_h≠pad_w, stride 2 — bit-identical across tile sizes and
        // thread counts to the untiled reference kernel
        let mut rng = Rng::seed_from_u64(67);
        let w = rand_t(&mut rng, &[6, 2, 3, 5]);
        let b = rand_t(&mut rng, &[6]);
        let x = rand_t(&mut rng, &[2, 6, 9, 11]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.08));
        let geo = ConvGeometry { kh: 3, kw: 5, stride: 2, pad_h: 1, pad_w: 2, groups: 3 };
        let (want, want_counts) = ConvEngine::forward_packed_reference(&p, &b, geo, &x).unwrap();
        // oh = (9 + 2·1 − 3)/2 + 1 = 5, ow = (11 + 2·2 − 5)/2 + 1 = 6
        assert_eq!(want.shape(), &[2, 6, 5, 6]);
        for tile in [1usize, 4, 1000] {
            for threads in [1usize, 3] {
                let eng = ConvEngine::with_tile_rows(threads, tile).unwrap();
                let (got, counts) = eng.forward_packed(&p, &b, geo, &x).unwrap();
                assert_eq!(got.data(), want.data(), "tile {tile} t={threads} diverged");
                assert_eq!(counts, want_counts);
            }
        }
    }

    #[test]
    fn bad_grouped_geometry_is_typed() {
        let mut rng = Rng::seed_from_u64(71);
        let w = rand_t(&mut rng, &[4, 2, 3, 3]);
        let b = Tensor::zeros(&[4]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.0));
        let eng = ConvEngine::serial();
        let x = rand_t(&mut rng, &[1, 4, 8, 8]);
        // 4 filters don't split into 3 groups
        let geo = ConvGeometry { kh: 3, kw: 3, stride: 1, pad_h: 0, pad_w: 0, groups: 3 };
        match eng.forward_packed(&p, &b, geo, &x) {
            Err(SubaccelError::InvalidConfig { field: "groups", .. }) => {}
            other => panic!("expected InvalidConfig(groups), got {other:?}"),
        }
        // zero groups and zero stride are config errors, not panics
        let geo = ConvGeometry { groups: 0, ..ConvGeometry::valid(3, 3) };
        assert!(matches!(
            eng.forward_packed(&p, &b, geo, &x),
            Err(SubaccelError::InvalidConfig { field: "groups", .. })
        ));
        let geo = ConvGeometry { stride: 0, ..ConvGeometry::valid(3, 3) };
        assert!(matches!(
            eng.forward_packed(&p, &b, geo, &x),
            Err(SubaccelError::InvalidConfig { field: "stride", .. })
        ));
        // channel count that doesn't give groups·k_len per patch → typed
        // mismatch reporting the grouped expectation
        let geo = ConvGeometry { kh: 3, kw: 3, stride: 1, pad_h: 0, pad_w: 0, groups: 2 };
        let bad = rand_t(&mut rng, &[1, 6, 8, 8]);
        assert_eq!(
            eng.forward_packed(&p, &b, geo, &bad).unwrap_err(),
            SubaccelError::KernelMismatch { expected_k: 2 * 18, got_k: 6 * 9 }
        );
    }

    #[test]
    fn tile_rows_env_values_parse_or_warn() {
        // valid overrides parse (whitespace tolerated)
        assert_eq!(parse_tile_rows("8"), Ok(Some(8)));
        assert_eq!(parse_tile_rows(" 16 "), Ok(Some(16)));
        // empty/whitespace is "unset", not an error
        assert_eq!(parse_tile_rows(""), Ok(None));
        assert_eq!(parse_tile_rows("   "), Ok(None));
        // zero and garbage are *reported*, never silently swallowed
        for bad in ["0", "abc", "-3", "1.5"] {
            let err = parse_tile_rows(bad).unwrap_err();
            assert!(err.contains("SUBACCEL_TILE_ROWS"), "{bad}: {err}");
        }
    }

    #[test]
    fn per_call_tile_is_bit_identical_and_validated() {
        let mut rng = Rng::seed_from_u64(101);
        let x = rand_t(&mut rng, &[2, 3, 10, 10]);
        let w = rand_t(&mut rng, &[4, 3, 3, 3]);
        let b = rand_t(&mut rng, &[4]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.07));
        let geo = ConvGeometry::valid(3, 3);
        let (want, _) = ConvEngine::forward_packed_reference(&p, &b, geo, &x).unwrap();
        for threads in [1usize, 3] {
            let eng = ConvEngine::new(threads).unwrap();
            let mut buf = Vec::new();
            for tile in [None, Some(1), Some(5), Some(4096)] {
                eng.forward_packed_tiled_slice_into(
                    &p,
                    b.data(),
                    geo,
                    x.data(),
                    x.shape(),
                    tile,
                    &mut buf,
                )
                .unwrap();
                assert_eq!(&buf[..], want.data(), "tile {tile:?} t={threads} diverged");
            }
            // a zero per-call tile is the same typed error as the
            // constructor's
            let err = eng
                .forward_packed_tiled_slice_into(
                    &p,
                    b.data(),
                    geo,
                    x.data(),
                    x.shape(),
                    Some(0),
                    &mut buf,
                )
                .unwrap_err();
            assert!(matches!(err, SubaccelError::InvalidConfig { field: "tile_rows", .. }));
        }
        // an engine-wide override out-prioritises the per-call request
        // (numerics can't distinguish them — that is the point — so this
        // just pins that the path accepts both at once)
        let eng = ConvEngine::with_tile_rows(2, 7).unwrap();
        let mut buf = Vec::new();
        eng.forward_packed_tiled_slice_into(&p, b.data(), geo, x.data(), x.shape(), Some(3), &mut buf)
            .unwrap();
        assert_eq!(&buf[..], want.data());
    }

    #[test]
    fn chunk_queue_serial_drain_covers_exactly_once() {
        for rows in [0usize, 1, 5, 12, 40] {
            for chunk in [1usize, 3, 7, 64] {
                let q = ChunkQueue::new(rows, chunk);
                let mut hits = vec![0u32; rows];
                let mut claims = 0;
                while let Some((a, b)) = q.claim() {
                    assert!(a < b && b <= rows, "bad claim {a}..{b} of {rows}");
                    for h in &mut hits[a..b] {
                        *h += 1;
                    }
                    claims += 1;
                }
                assert_eq!(claims, q.n_chunks());
                assert!(hits.iter().all(|&h| h == 1), "rows={rows} chunk={chunk}: {hits:?}");
                // dry queue stays dry
                assert_eq!(q.claim(), None);
            }
        }
    }

    #[test]
    fn steal_chunk_bounds() {
        // never zero, and sub-tile only when rows are scarce
        for rows in [1usize, 6, 100, 729, 100_000] {
            for tile in [1usize, 2, 16, 64] {
                for threads in [1usize, 4, 8] {
                    let c = steal_chunk_rows(rows, tile, threads);
                    assert!(c >= 1);
                    if c > tile {
                        // super-tile chunks are whole tiles
                        assert_eq!(c % tile, 0, "rows={rows} tile={tile} t={threads}");
                    }
                }
            }
        }
        // few rows, many threads: single-row chunks engage every core
        assert_eq!(steal_chunk_rows(6, 16, 8), 1);
        // plentiful rows: about 4 claims per thread, tile-aligned
        let c = steal_chunk_rows(729, 2, 8);
        assert_eq!(c % 2, 0);
        let claims = (729 + c - 1) / c;
        assert!((16..=64).contains(&claims), "claims={claims}");
    }

    #[test]
    fn poisoned_engine_lock_still_serves() {
        let mut rng = Rng::seed_from_u64(83);
        let w = rand_t(&mut rng, &[3, 2, 3, 3]);
        let b = rand_t(&mut rng, &[3]);
        let x = rand_t(&mut rng, &[1, 2, 9, 9]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.1));
        let geo = ConvGeometry::valid(3, 3);
        let eng = ConvEngine::new(2).unwrap();
        let (want, _) = eng.forward_packed(&p, &b, geo, &x).unwrap();
        // poison the scratch lock: a thread panics while holding it
        let panicked = std::thread::scope(|s| {
            s.spawn(|| {
                let _guard = eng.inner.lock().unwrap();
                panic!("poisoning the engine lock on purpose");
            })
            .join()
        });
        assert!(panicked.is_err());
        assert!(eng.inner.is_poisoned());
        // scratch is re-derivable, so the engine recovers and still
        // computes the exact same result
        let (got, _) = eng.forward_packed(&p, &b, geo, &x).unwrap();
        assert_eq!(got.data(), want.data());
    }
}
