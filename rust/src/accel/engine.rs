//! Parallel execution engine for paired convolution.
//!
//! Two ideas, both borrowed from how multiplier-less hardware actually
//! wins (TMA, arXiv:1909.04551; weight-sharing MAC units,
//! arXiv:1801.10219): a cache-friendly layout and wide parallelism over
//! cheap ops.
//!
//! * [`PackedPairing`] — a structure-of-arrays view of a
//!   [`LayerPairing`]: all filters' `(i1, i2, k)` triples and
//!   `(idx, w)` MAC taps live in five flat arrays with CSR-style
//!   per-filter offset tables. The hot loop walks contiguous slices
//!   instead of chasing a `Vec<FilterPairing>` of small heap blocks.
//! * [`ConvEngine`] — a persistent std-thread worker pool (the vendored
//!   set has no async runtime; this matches the coordinator's
//!   thread+channel design) that shards im2col rows across cores. The
//!   engine owns reusable scratch buffers, so a steady-state
//!   [`ConvEngine::forward_packed_into`] call performs **zero heap
//!   allocation**. [`ConvEngine::forward_packed_slice_into`] is the same
//!   path on raw activation slices, for the whole-network plans in
//!   [`crate::exec`].
//!
//! Numerics: every shard runs the same [`compute_rows`] kernel in the
//! same iteration order, and Rust f32 arithmetic is strict — so the
//! multi-threaded result is **bit-identical** to the serial one (and to
//! `SubConv2d::forward`, which delegates here). Property-tested in
//! `rust/tests/prop_engine.rs`.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

use super::preprocess::{FilterPairing, LayerPairing};
use crate::error::SubaccelError;
use crate::nn::OpCounts;
use crate::tensor::{im2col_slice_into, Tensor};

/// Spatial geometry of a conv layer (everything [`ConvEngine`] needs
/// beyond the pairing itself).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeometry {
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGeometry {
    /// Valid convolution, stride 1 (LeNet geometry).
    pub fn valid(kh: usize, kw: usize) -> Self {
        Self { kh, kw, stride: 1, pad: 0 }
    }
}

/// Output geometry of one engine forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvOutShape {
    pub batch: usize,
    pub cout: usize,
    pub out_h: usize,
    pub out_w: usize,
}

impl ConvOutShape {
    pub fn dims(&self) -> [usize; 4] {
        [self.batch, self.cout, self.out_h, self.out_w]
    }
}

/// Structure-of-arrays layout of a whole layer's pairing.
///
/// Filter `c`'s subtractor triples are
/// `pair_i1/pair_i2/pair_k[pair_off[c] .. pair_off[c+1]]`, its MAC taps
/// `unp_idx/unp_w[unp_off[c] .. unp_off[c+1]]`. Built once at compile
/// time ([`PackedPairing::from_layer`]); round-trips losslessly
/// ([`PackedPairing::to_layer`]).
#[derive(Debug, Clone)]
pub struct PackedPairing {
    cout: usize,
    k_len: usize,
    shape: Vec<usize>,
    rounding: f32,
    pair_i1: Vec<u32>,
    pair_i2: Vec<u32>,
    pair_k: Vec<f32>,
    unp_idx: Vec<u32>,
    unp_w: Vec<f32>,
    /// `cout + 1` offsets into the pair arrays.
    pair_off: Vec<u32>,
    /// `cout + 1` offsets into the unpaired arrays.
    unp_off: Vec<u32>,
}

impl PackedPairing {
    /// Flatten a [`LayerPairing`] into the packed layout.
    pub fn from_layer(lp: &LayerPairing) -> Self {
        let cout = lp.filters.len();
        let n_pairs: usize = lp.filters.iter().map(|f| f.n_pairs()).sum();
        let n_unp: usize = lp.filters.iter().map(|f| f.n_unpaired()).sum();
        let mut p = Self {
            cout,
            k_len: lp.k_len,
            shape: lp.shape.clone(),
            rounding: lp.rounding,
            pair_i1: Vec::with_capacity(n_pairs),
            pair_i2: Vec::with_capacity(n_pairs),
            pair_k: Vec::with_capacity(n_pairs),
            unp_idx: Vec::with_capacity(n_unp),
            unp_w: Vec::with_capacity(n_unp),
            pair_off: Vec::with_capacity(cout + 1),
            unp_off: Vec::with_capacity(cout + 1),
        };
        p.pair_off.push(0);
        p.unp_off.push(0);
        for f in &lp.filters {
            p.pair_i1.extend_from_slice(&f.pair_i1);
            p.pair_i2.extend_from_slice(&f.pair_i2);
            p.pair_k.extend_from_slice(&f.pair_k);
            p.unp_idx.extend_from_slice(&f.unp_idx);
            p.unp_w.extend_from_slice(&f.unp_w);
            p.pair_off.push(p.pair_k.len() as u32);
            p.unp_off.push(p.unp_w.len() as u32);
        }
        p
    }

    /// Reconstruct the per-filter representation (lossless inverse of
    /// [`PackedPairing::from_layer`]).
    pub fn to_layer(&self) -> LayerPairing {
        let filters = (0..self.cout)
            .map(|c| {
                let (i1, i2, k) = self.pairs(c);
                let (ui, uw) = self.unpaired(c);
                FilterPairing {
                    pair_i1: i1.to_vec(),
                    pair_i2: i2.to_vec(),
                    pair_k: k.to_vec(),
                    unp_idx: ui.to_vec(),
                    unp_w: uw.to_vec(),
                }
            })
            .collect();
        LayerPairing {
            filters,
            k_len: self.k_len,
            shape: self.shape.clone(),
            rounding: self.rounding,
        }
    }

    /// Filter `c`'s subtractor triples `(i1, i2, k)`.
    #[inline]
    pub fn pairs(&self, c: usize) -> (&[u32], &[u32], &[f32]) {
        let (a, b) = (self.pair_off[c] as usize, self.pair_off[c + 1] as usize);
        (&self.pair_i1[a..b], &self.pair_i2[a..b], &self.pair_k[a..b])
    }

    /// Filter `c`'s ordinary MAC taps `(idx, w)`.
    #[inline]
    pub fn unpaired(&self, c: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.unp_off[c] as usize, self.unp_off[c + 1] as usize);
        (&self.unp_idx[a..b], &self.unp_w[a..b])
    }

    pub fn cout(&self) -> usize {
        self.cout
    }

    /// Flattened filter length `Cin·kh·kw`.
    pub fn k_len(&self) -> usize {
        self.k_len
    }

    /// Original OIHW weight shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn total_pairs(&self) -> usize {
        self.pair_k.len()
    }

    pub fn total_unpaired(&self) -> usize {
        self.unp_w.len()
    }
}

/// One worker's slice of a forward: raw views into the engine's scratch
/// buffers plus the caller's pairing/bias. Sound because the dispatching
/// thread holds the engine lock and blocks on the done channel until
/// every shard is finished, and shards write disjoint `out` regions
/// carved with `split_at_mut`.
struct Shard {
    patches: *const f32,
    patches_len: usize,
    out: *mut f32,
    out_len: usize,
    packed: *const PackedPairing,
    bias: *const f32,
    bias_len: usize,
    k: usize,
}

// Raw pointers strip auto-Send; the dispatch protocol above restores the
// guarantee (exclusive disjoint writes, caller outlives the shard).
unsafe impl Send for Shard {}

struct Pool {
    job_txs: Vec<Sender<Shard>>,
    done_rx: Receiver<()>,
}

struct Scratch {
    patches: Vec<f32>,
    rowmajor: Vec<f32>,
}

struct Inner {
    scratch: Scratch,
    pool: Option<Pool>,
}

/// Multi-threaded paired-conv executor with persistent workers and
/// reusable scratch. Cheap to share (`Arc<ConvEngine>`); one engine per
/// coordinator replica is the intended granularity.
///
/// `Sync` by construction: all mutable state (scratch and the pool's
/// `mpsc` endpoints, which are `!Sync`) sits behind one internal mutex,
/// so concurrent `forward_*` calls serialize rather than race.
pub struct ConvEngine {
    threads: usize,
    inner: Mutex<Inner>,
    handles: Vec<JoinHandle<()>>,
}

impl ConvEngine {
    /// Build an engine running on `threads` OS threads total (the
    /// calling thread counts as one; `threads - 1` workers are spawned).
    pub fn new(threads: usize) -> Result<Self, SubaccelError> {
        if threads == 0 {
            return Err(SubaccelError::InvalidConfig {
                field: "threads",
                reason: "engine needs at least one thread".into(),
            });
        }
        let scratch = Scratch { patches: Vec::new(), rowmajor: Vec::new() };
        let (pool, handles) = if threads == 1 {
            (None, Vec::new())
        } else {
            let (done_tx, done_rx) = channel();
            let mut job_txs = Vec::with_capacity(threads - 1);
            let mut handles = Vec::with_capacity(threads - 1);
            for i in 0..threads - 1 {
                let (tx, rx) = channel::<Shard>();
                let done = done_tx.clone();
                let h = std::thread::Builder::new()
                    .name(format!("conv-engine-{i}"))
                    .spawn(move || worker_loop(rx, done))
                    .map_err(|e| SubaccelError::InvalidConfig {
                        field: "threads",
                        reason: format!("failed to spawn worker: {e}"),
                    })?;
                job_txs.push(tx);
                handles.push(h);
            }
            (Some(Pool { job_txs, done_rx }), handles)
        };
        Ok(Self { threads, inner: Mutex::new(Inner { scratch, pool }), handles })
    }

    /// Single-threaded engine (no workers; runs inline on the caller).
    pub fn serial() -> Self {
        Self::new(1).expect("1 thread is always valid")
    }

    /// Number of OS threads this engine computes on.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Detected host parallelism (≥ 1), for `--threads 0`-style auto
    /// configuration.
    pub fn host_threads() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Run a paired conv layer, allocating the output tensor.
    pub fn forward_packed(
        &self,
        packed: &PackedPairing,
        bias: &Tensor,
        geo: ConvGeometry,
        x: &Tensor,
    ) -> Result<(Tensor, OpCounts), SubaccelError> {
        let mut buf = Vec::new();
        let (os, counts) = self.forward_packed_into(packed, bias.data(), geo, x, &mut buf)?;
        Ok((Tensor::new(&os.dims(), buf), counts))
    }

    /// Run a paired conv layer into a caller-owned buffer (resized and
    /// fully overwritten). With a warm buffer this path performs zero
    /// heap allocation: im2col patches and the row-major intermediate
    /// live in engine scratch reused across calls.
    ///
    /// Errors with [`SubaccelError::KernelMismatch`] when the input's
    /// per-patch length differs from what the pairing was compiled for;
    /// non-NCHW inputs and bias-length mismatches are programming errors
    /// and panic (matching the crate's assert conventions).
    pub fn forward_packed_into(
        &self,
        packed: &PackedPairing,
        bias: &[f32],
        geo: ConvGeometry,
        x: &Tensor,
        out: &mut Vec<f32>,
    ) -> Result<(ConvOutShape, OpCounts), SubaccelError> {
        self.forward_packed_slice_into(packed, bias, geo, x.data(), x.shape(), out)
    }

    /// [`ConvEngine::forward_packed_into`] on a raw NCHW activation
    /// slice — the [`crate::exec`] executor's entry point. Whole-network
    /// plans keep activations in reusable ping-pong scratch rather than
    /// `Tensor`s, so no tensor handle (whose shape vector would
    /// allocate) exists on the steady-state path.
    pub fn forward_packed_slice_into(
        &self,
        packed: &PackedPairing,
        bias: &[f32],
        geo: ConvGeometry,
        xd: &[f32],
        xshape: &[usize],
        out: &mut Vec<f32>,
    ) -> Result<(ConvOutShape, OpCounts), SubaccelError> {
        assert_eq!(bias.len(), packed.cout, "bias length != Cout");
        let inner = &mut *self.inner.lock().expect("engine lock");
        let Inner { scratch, pool } = inner;

        let s = im2col_slice_into(
            xd,
            xshape,
            geo.kh,
            geo.kw,
            geo.stride,
            geo.pad,
            &mut scratch.patches,
        );
        if s.k != packed.k_len {
            return Err(SubaccelError::KernelMismatch {
                expected_k: packed.k_len,
                got_k: s.k,
            });
        }
        let (rows, cout) = (s.rows, packed.cout);
        scratch.rowmajor.resize(rows * cout, 0.0);

        match pool {
            None => compute_rows(
                &scratch.patches[..rows * s.k],
                s.k,
                packed,
                bias,
                &mut scratch.rowmajor[..],
            ),
            Some(pool) => {
                let chunk = (rows + self.threads - 1) / self.threads;
                let mut rest_out: &mut [f32] = &mut scratch.rowmajor[..];
                let mut rest_p: &[f32] = &scratch.patches[..rows * s.k];

                // shard 0 stays on the calling thread
                let take0 = chunk.min(rows);
                let (out0, r) = std::mem::take(&mut rest_out).split_at_mut(take0 * cout);
                rest_out = r;
                let (p0, rp) = rest_p.split_at(take0 * s.k);
                rest_p = rp;

                // remaining shards go to the workers (≤ threads − 1 of
                // them, since chunk = ⌈rows / threads⌉)
                let mut off = take0;
                let mut sent = 0usize;
                while off < rows {
                    let take = chunk.min(rows - off);
                    let (o, r) = std::mem::take(&mut rest_out).split_at_mut(take * cout);
                    rest_out = r;
                    let (p, rp) = rest_p.split_at(take * s.k);
                    rest_p = rp;
                    let shard = Shard {
                        patches: p.as_ptr(),
                        patches_len: p.len(),
                        out: o.as_mut_ptr(),
                        out_len: o.len(),
                        packed: packed as *const PackedPairing,
                        bias: bias.as_ptr(),
                        bias_len: bias.len(),
                        k: s.k,
                    };
                    pool.job_txs[sent].send(shard).expect("conv-engine worker died");
                    sent += 1;
                    off += take;
                }
                compute_rows(p0, s.k, packed, bias, out0);
                for _ in 0..sent {
                    pool.done_rx.recv().expect("conv-engine worker died");
                }
            }
        }

        // (rows, Cout) → (B, Cout, OH, OW)
        let (b, oh, ow) = (s.batch, s.out_h, s.out_w);
        out.resize(rows * cout, 0.0);
        for bi in 0..b {
            for y in 0..oh {
                for xw in 0..ow {
                    let r = (bi * oh + y) * ow + xw;
                    for c in 0..cout {
                        out[((bi * cout + c) * oh + y) * ow + xw] =
                            scratch.rowmajor[r * cout + c];
                    }
                }
            }
        }

        let counts = OpCounts::paired_layer(
            packed.total_pairs() as u64,
            packed.total_unpaired() as u64,
            rows as u64,
            (rows * cout) as u64,
        );
        Ok((ConvOutShape { batch: b, cout, out_h: oh, out_w: ow }, counts))
    }
}

impl Drop for ConvEngine {
    fn drop(&mut self) {
        // Dropping the senders ends each worker's recv loop.
        if let Ok(mut g) = self.inner.lock() {
            g.pool = None;
        }
        for h in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Receiver<Shard>, done: Sender<()>) {
    while let Ok(shard) = rx.recv() {
        // Safety: the dispatcher holds the engine lock and blocks until
        // our done token arrives, so these views outlive this block; the
        // out region is exclusively ours (split_at_mut).
        unsafe {
            let patches = std::slice::from_raw_parts(shard.patches, shard.patches_len);
            let out = std::slice::from_raw_parts_mut(shard.out, shard.out_len);
            let bias = std::slice::from_raw_parts(shard.bias, shard.bias_len);
            compute_rows(patches, shard.k, &*shard.packed, bias, out);
        }
        if done.send(()).is_err() {
            break;
        }
    }
}

/// The shared kernel: paired conv over a contiguous block of im2col
/// rows. Every path through the engine — serial, caller shard, worker
/// shard — runs exactly this code in exactly this order, which is what
/// makes thread counts bit-identical (strict f32 + fixed summation
/// order). The zip/sum shapes mirror the original `SubConv2d` hot loop,
/// preserving its numerics; the slices now come from the packed layout,
/// so the filter walk is contiguous.
fn compute_rows(patches: &[f32], k: usize, packed: &PackedPairing, bias: &[f32], out: &mut [f32]) {
    let cout = packed.cout;
    let rows = out.len() / cout;
    for r in 0..rows {
        let patch = &patches[r * k..(r + 1) * k];
        for c in 0..cout {
            // subtractor lane: k·(I1 − I2) per combined pair
            let (i1, i2, kk) = packed.pairs(c);
            let pair_acc: f32 = i1
                .iter()
                .zip(i2)
                .zip(kk)
                .map(|((&a, &b), &kv)| kv * (patch[a as usize] - patch[b as usize]))
                .sum();
            // ordinary MAC lane
            let (ui, uw) = packed.unpaired(c);
            let mac_acc: f32 =
                ui.iter().zip(uw).map(|(&iu, &wv)| wv * patch[iu as usize]).sum();
            out[r * cout + c] = bias[c] + pair_acc + mac_acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect())
    }

    #[test]
    fn zero_threads_is_a_typed_config_error() {
        match ConvEngine::new(0) {
            Err(SubaccelError::InvalidConfig { field, .. }) => assert_eq!(field, "threads"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn packed_offsets_are_consistent() {
        let mut rng = Rng::seed_from_u64(21);
        let w = rand_t(&mut rng, &[5, 3, 4, 4]);
        let lp = LayerPairing::from_weights(&w, 0.1);
        let p = PackedPairing::from_layer(&lp);
        assert_eq!(p.cout(), 5);
        assert_eq!(p.k_len(), 48);
        assert_eq!(p.total_pairs(), lp.total_pairs());
        for (c, f) in lp.filters.iter().enumerate() {
            let (i1, i2, k) = p.pairs(c);
            assert_eq!(i1, &f.pair_i1[..]);
            assert_eq!(i2, &f.pair_i2[..]);
            assert_eq!(k, &f.pair_k[..]);
            let (ui, uw) = p.unpaired(c);
            assert_eq!(ui, &f.unp_idx[..]);
            assert_eq!(uw, &f.unp_w[..]);
        }
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        let mut rng = Rng::seed_from_u64(7);
        let x = rand_t(&mut rng, &[2, 3, 11, 11]);
        let w = rand_t(&mut rng, &[6, 3, 3, 3]);
        let b = rand_t(&mut rng, &[6]);
        let lp = LayerPairing::from_weights(&w, 0.08);
        let p = PackedPairing::from_layer(&lp);
        let geo = ConvGeometry::valid(3, 3);

        let serial = ConvEngine::serial();
        let (want, want_counts) = serial.forward_packed(&p, &b, geo, &x).unwrap();
        for threads in 2..=4 {
            let eng = ConvEngine::new(threads).unwrap();
            let (got, counts) = eng.forward_packed(&p, &b, geo, &x).unwrap();
            assert_eq!(got.shape(), want.shape());
            assert_eq!(got.data(), want.data(), "{threads} threads diverged");
            assert_eq!(counts, want_counts);
        }
    }

    #[test]
    fn strided_padded_geometry_runs() {
        let mut rng = Rng::seed_from_u64(13);
        let x = rand_t(&mut rng, &[1, 3, 16, 16]);
        let w = rand_t(&mut rng, &[4, 3, 5, 5]);
        let b = rand_t(&mut rng, &[4]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.05));
        let eng = ConvEngine::new(3).unwrap();
        let geo = ConvGeometry { kh: 5, kw: 5, stride: 2, pad: 2 };
        let (y, _) = eng.forward_packed(&p, &b, geo, &x).unwrap();
        assert_eq!(y.shape(), &[1, 4, 8, 8]);
        // matches the serial engine bit-for-bit on the same geometry
        let (y1, _) = ConvEngine::serial().forward_packed(&p, &b, geo, &x).unwrap();
        assert_eq!(y.data(), y1.data());
    }

    #[test]
    fn kernel_mismatch_is_typed() {
        let mut rng = Rng::seed_from_u64(3);
        let w = rand_t(&mut rng, &[2, 2, 3, 3]);
        let b = Tensor::zeros(&[2]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.0));
        let x = rand_t(&mut rng, &[1, 3, 8, 8]); // 3 channels ≠ 2
        let err = ConvEngine::serial()
            .forward_packed(&p, &b, ConvGeometry::valid(3, 3), &x)
            .unwrap_err();
        assert_eq!(err, SubaccelError::KernelMismatch { expected_k: 18, got_k: 27 });
    }

    #[test]
    fn reused_buffer_is_fully_overwritten() {
        let mut rng = Rng::seed_from_u64(31);
        let w = rand_t(&mut rng, &[3, 2, 3, 3]);
        let b = rand_t(&mut rng, &[3]);
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, 0.1));
        let eng = ConvEngine::new(2).unwrap();
        let geo = ConvGeometry::valid(3, 3);
        let big = rand_t(&mut rng, &[2, 2, 10, 10]);
        let small = rand_t(&mut rng, &[1, 2, 5, 5]);
        let mut buf = Vec::new();
        eng.forward_packed_into(&p, b.data(), geo, &big, &mut buf).unwrap();
        let (os, _) = eng.forward_packed_into(&p, b.data(), geo, &small, &mut buf).unwrap();
        assert_eq!(buf.len(), os.dims().iter().product::<usize>());
        let (fresh, _) = eng.forward_packed(&p, &b, geo, &small).unwrap();
        assert_eq!(&buf[..], fresh.data());
    }
}
