//! Ablation: alternative pairing policies for Algorithm 1.
//!
//! The paper's two-pointer walk pairs weights in ascending-magnitude
//! order and snaps each pair to its mean. Two natural questions a
//! hardware team would ask before taping out:
//!
//! 1. *Does the greedy walk leave pairs on the table?* — compare against
//!    a closest-gap-first matcher ([`pair_filter_closest_first`]).
//! 2. *Does pairing order matter for accuracy?* — closest-first minimizes
//!    per-pair snap error locally; the two-pointer maximizes coverage.
//!
//! `benches/ablation_matching.rs` runs both policies over the trained
//! model and reports pairs / total snap error / accuracy per rounding.

use super::preprocess::FilterPairing;

/// Closest-gap-first matching: enumerate all (pos, neg) candidates whose
/// magnitude gap is inside the rounding window, take them greedily in
/// ascending-gap order while both endpoints are free.
///
/// O(P·N log(P·N)) per filter — fine offline for K ≤ a few thousand.
pub fn pair_filter_closest_first(w: &[f32], rounding: f32) -> FilterPairing {
    let mut res = FilterPairing::default();
    let mut pos: Vec<(f32, u32)> = Vec::new();
    let mut neg: Vec<(f32, u32)> = Vec::new();
    for (i, &v) in w.iter().enumerate() {
        if v > 0.0 {
            pos.push((v, i as u32));
        } else if v < 0.0 {
            neg.push((v, i as u32));
        } else {
            res.unp_idx.push(i as u32);
            res.unp_w.push(v);
        }
    }
    // candidate edges inside the window, sorted by gap
    let mut edges: Vec<(f32, usize, usize)> = Vec::new();
    for (pi, &(pv, _)) in pos.iter().enumerate() {
        for (ni, &(nv, _)) in neg.iter().enumerate() {
            let gap = (pv - (-nv)).abs();
            if gap < rounding {
                edges.push((gap, pi, ni));
            }
        }
    }
    edges.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut pos_used = vec![false; pos.len()];
    let mut neg_used = vec![false; neg.len()];
    for (_, pi, ni) in edges {
        if pos_used[pi] || neg_used[ni] {
            continue;
        }
        pos_used[pi] = true;
        neg_used[ni] = true;
        let (pv, pidx) = pos[pi];
        let (nv, nidx) = neg[ni];
        res.pair_i1.push(pidx);
        res.pair_i2.push(nidx);
        res.pair_k.push((pv + (-nv)) / 2.0);
    }
    for (used, list) in [(&pos_used, &pos), (&neg_used, &neg)] {
        for (u, &(v, i)) in used.iter().zip(list.iter()) {
            if !u {
                res.unp_idx.push(i);
                res.unp_w.push(v);
            }
        }
    }
    res
}

/// Total snap error of a pairing: Σ |k − |w|| over both pair members.
pub fn total_snap_error(w: &[f32], p: &FilterPairing) -> f64 {
    let mut e = 0.0f64;
    for j in 0..p.n_pairs() {
        let k = p.pair_k[j] as f64;
        e += (k - w[p.pair_i1[j] as usize] as f64).abs();
        e += (k + w[p.pair_i2[j] as usize] as f64).abs();
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::pair_filter;
    use crate::util::forall;

    #[test]
    fn conservation_and_window() {
        forall("closest-first invariants", 0xAB1A, 120, |g| {
            let w = g.weights(120, 1.0);
            let r = g.rng.range(0.0, 0.5);
            let p = pair_filter_closest_first(&w, r);
            if 2 * p.n_pairs() + p.n_unpaired() != w.len() {
                return Err("weight count not conserved".into());
            }
            for j in 0..p.n_pairs() {
                let ka = w[p.pair_i1[j] as usize];
                let kb = w[p.pair_i2[j] as usize];
                if !(ka > 0.0 && kb < 0.0 && (ka + kb).abs() < r) {
                    return Err(format!("bad pair ({ka}, {kb}) at rounding {r}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn two_pointer_never_finds_fewer_pairs() {
        // The paper's two-pointer walk is a maximum matching for this
        // interval structure; closest-first is at best equal.
        forall("two-pointer optimality", 0xAB1B, 120, |g| {
            let w = g.weights(100, 1.0);
            let r = g.rng.range(0.0, 0.5);
            let a = pair_filter(&w, r).n_pairs();
            let b = pair_filter_closest_first(&w, r).n_pairs();
            if a < b {
                return Err(format!("two-pointer found {a} pairs, closest-first {b}"));
            }
            Ok(())
        });
    }

    #[test]
    fn closest_first_min_gap_on_triples() {
        // pos 0.50 can pair with −0.48 (gap .02) or −0.55 (gap .05);
        // closest-first must take the .02 partner
        let w = [0.50f32, -0.48, -0.55];
        let p = pair_filter_closest_first(&w, 0.1);
        assert_eq!(p.n_pairs(), 1);
        assert_eq!(p.pair_i2[0], 1);
        // the paper's walk (ascending magnitude) pairs 0.50 with −0.48 too
        let q = pair_filter(&w, 0.1);
        assert_eq!(q.n_pairs(), 1);
    }

    #[test]
    fn snap_error_metric() {
        let w = [0.5f32, -0.4];
        let p = pair_filter_closest_first(&w, 0.2);
        assert_eq!(p.n_pairs(), 1);
        // k = 0.45; error = |0.45-0.5| + |0.45-0.4| = 0.1
        assert!((total_snap_error(&w, &p) - 0.1).abs() < 1e-6);
    }
}
