//! Plan-warm row-tile autotuning.
//!
//! [`tile_rows_heuristic`] picks a sane tile from layer *shape* alone,
//! but the best tile also depends on the host (cache sizes, core count,
//! memory bandwidth) and on how many rows the layer actually has at the
//! planned batch size. This module runs a **one-shot bounded sweep** per
//! conv layer at plan-warm time — when allocation is already allowed and
//! the hot path has not started — and records the winner in the
//! [`crate::exec::ExecutionPlan`], which then passes it to the engine as
//! a per-call tile on every forward.
//!
//! Override precedence, highest first (pinned by
//! `rust/tests/prop_autotune.rs` and ARCHITECTURE.md):
//!
//! 1. `SUBACCEL_TILE_ROWS` (env, read at engine construction) — a hard
//!    override; the sweep is skipped entirely.
//! 2. [`ConvEngine::with_tile_rows`] (constructor) — same mechanism,
//!    same skip.
//! 3. The autotuned decision (this module), including warm-starts from a
//!    recorded [`TileCache`] trajectory.
//! 4. [`tile_rows_heuristic`] — what the engine falls back to when
//!    nothing above produced a tile.
//!
//! Two sweep modes, chosen by [`AutotuneBudget::repeats`]:
//!
//! * `repeats == 0` — **deterministic cost model**: candidates are
//!   scored by estimated memory traffic (tap tables re-streamed once per
//!   tile; gathers from a strip that spilled L1 are penalised). No
//!   clocks are read, so the decision is a pure function of the layer
//!   and budget — identical on every host, every run, every thread
//!   count. This is the default (serving replicas must warm
//!   deterministically).
//! * `repeats > 0` — **measured sweep**: each candidate tile runs the
//!   real layer on a seeded synthetic input through the real engine,
//!   best-of-`repeats` wall time wins. Used by `benches/conv_hotpath.rs`
//!   where the trajectory records real numbers.
//!
//! Numerics are never at stake: the tile only regroups independent
//! output elements ([`crate::accel::engine`] docs), so *any* decision is
//! bit-identical to any other — the sweep can be greedy, noisy, or
//! cached without perturbing a single logit.

use std::collections::HashMap;
use std::time::Instant;

use super::engine::{tile_rows_heuristic, ConvEngine, ConvGeometry, PackedPairing};
use crate::tensor::im2col_shape;
use crate::util::{json_field_f64, JsonReport, Rng};

/// Bounds for one autotune sweep. `Default` is the deterministic
/// cost-model mode; [`AutotuneBudget::measured`] turns on timing.
#[derive(Debug, Clone, PartialEq)]
pub struct AutotuneBudget {
    /// Maximum candidate tiles scored per conv layer (the candidate
    /// ladder is truncated toward the heuristic seed when longer).
    pub candidates: usize,
    /// Timed repeats per candidate; `0` selects the deterministic cost
    /// model (no clocks, no synthetic input).
    pub repeats: usize,
    /// Batch size of the synthetic input timed in measured mode
    /// (clamped to the plan's batch; small keeps warm-up cheap).
    pub sample_batch: usize,
    /// Seed for the synthetic input. Fixed seed + fixed budget ⇒ the
    /// sweep itself is reproducible (modulo wall-clock noise in
    /// measured mode — which never affects correctness, only the tile).
    pub seed: u64,
}

impl Default for AutotuneBudget {
    fn default() -> Self {
        Self { candidates: 5, repeats: 0, sample_batch: 1, seed: 0xA070_707E }
    }
}

impl AutotuneBudget {
    /// Measured-sweep budget: best-of-`repeats` wall time per candidate
    /// (`repeats` is clamped to ≥ 1 — a measured sweep must measure).
    pub fn measured(repeats: usize) -> Self {
        Self { repeats: repeats.max(1), ..Self::default() }
    }
}

/// Where a layer's tile came from — the override-precedence rung that
/// produced it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileSource {
    /// `SUBACCEL_TILE_ROWS` or [`ConvEngine::with_tile_rows`]: the
    /// engine-wide hard override. The sweep was skipped.
    Override,
    /// Loaded from a recorded [`TileCache`] trajectory entry.
    WarmStart,
    /// Chosen by this run's sweep (cost model or measured).
    Autotuned,
    /// Sweep fallback (degenerate geometry): the plain heuristic.
    Heuristic,
}

impl TileSource {
    /// Stable lowercase label for trajectory records.
    pub fn as_str(&self) -> &'static str {
        match self {
            TileSource::Override => "override",
            TileSource::WarmStart => "warm-start",
            TileSource::Autotuned => "autotuned",
            TileSource::Heuristic => "heuristic",
        }
    }
}

/// One layer's tuning outcome, recorded in the plan and the trajectory.
#[derive(Debug, Clone, PartialEq)]
pub struct TileDecision {
    /// The plan step's name (e.g. `"c1"`).
    pub layer: String,
    /// The chosen row tile (≥ 1).
    pub tile_rows: usize,
    pub source: TileSource,
    /// The winner's score: best-of-repeats nanoseconds in measured
    /// mode, estimated traffic bytes in cost-model mode, `0.0` when no
    /// sweep ran (override / warm-start / fallback).
    pub score: f64,
    /// How many candidates were scored (`0` when no sweep ran).
    pub candidates: usize,
}

/// Candidate ladder around the heuristic seed: `{h/4, h/2, h, 2h, 4h}`
/// clamped to `[1, rows]`, deduplicated, and truncated toward `h` when
/// the budget allows fewer — so the heuristic itself is always in the
/// running, and no candidate exceeds the layer's actual row count
/// (tiles beyond `rows` all degenerate to one strip).
pub fn candidate_tiles(seed_tile: usize, rows: usize, budget: &AutotuneBudget) -> Vec<usize> {
    let h = seed_tile.max(1);
    let cap = rows.max(1);
    let mut cands: Vec<usize> =
        [h / 4, h / 2, h, h * 2, h * 4].iter().map(|&t| t.clamp(1, cap)).collect();
    cands.sort_unstable();
    cands.dedup();
    let keep = budget.candidates.max(1);
    // drop whichever end is (multiplicatively) farther from the seed
    while cands.len() > keep {
        let (lo, hi) = (cands[0], cands[cands.len() - 1]);
        // hi/h vs h/lo without division: hi·lo vs h²
        if hi * lo >= h.clamp(1, cap) * h.clamp(1, cap) {
            cands.pop();
        } else {
            cands.remove(0);
        }
    }
    cands
}

/// Deterministic per-forward traffic estimate for one candidate tile,
/// in bytes (lower is better):
///
/// * the tap tables (`≈ 8·taps` bytes) are re-streamed once per tile —
///   fewer, deeper tiles amortise them;
/// * once the strip (`4·tile·k` bytes) spills the ~24 KiB L1 budget,
///   every gather walks L2 instead — charged as an extra pass over the
///   `4·taps·rows` gathered bytes.
///
/// The two terms pull in opposite directions, which is the whole tension
/// [`tile_rows_heuristic`] resolves blindly and this model resolves with
/// the actual row count in hand.
fn tile_cost(tile: usize, k_full: usize, taps: usize, rows: usize) -> f64 {
    const L1_BYTES: f64 = 24.0 * 1024.0;
    let tiles = ((rows + tile - 1) / tile.max(1)).max(1) as f64;
    let table_bytes = tiles * 8.0 * taps as f64;
    let strip_bytes = 4.0 * (tile * k_full) as f64;
    let gather_bytes = 4.0 * taps as f64 * rows as f64;
    let spill = if strip_bytes > L1_BYTES { 2.0 * gather_bytes } else { 0.0 };
    table_bytes + spill
}

/// Sweep one conv layer and return its [`TileDecision`]. Infallible by
/// design: a hard engine override short-circuits to
/// [`TileSource::Override`], degenerate geometry falls back to
/// [`TileSource::Heuristic`], and in measured mode a forward error on
/// some candidate simply removes it from the running.
///
/// `in_shape` is the NCHW input the plan resolved for this layer; the
/// row count it implies (`B·OH·OW`) is what the candidates are scored
/// against.
pub fn autotune_conv(
    engine: &ConvEngine,
    packed: &PackedPairing,
    bias: &[f32],
    geo: ConvGeometry,
    in_shape: &[usize],
    layer: &str,
    budget: &AutotuneBudget,
) -> TileDecision {
    // Rung 1–2: env / constructor overrides are hard — no sweep.
    if let Some(t) = engine.tile_rows() {
        return TileDecision {
            layer: layer.to_string(),
            tile_rows: t,
            source: TileSource::Override,
            score: 0.0,
            candidates: 0,
        };
    }

    let heuristic = tile_rows_heuristic(packed.k_len(), packed.cout(), packed.total_taps());
    let fallback = |score: f64| TileDecision {
        layer: layer.to_string(),
        tile_rows: heuristic,
        source: TileSource::Heuristic,
        score,
        candidates: 0,
    };

    // Defensive geometry screen (im2col_shape panics on impossible
    // geometry; plans never produce one, but bench callers might).
    if in_shape.len() != 4
        || geo.stride == 0
        || geo.groups == 0
        || in_shape.iter().any(|&d| d == 0)
        || in_shape[2] + 2 * geo.pad_h < geo.kh
        || in_shape[3] + 2 * geo.pad_w < geo.kw
        || in_shape[1] * geo.kh * geo.kw != geo.groups * packed.k_len()
    {
        return fallback(0.0);
    }
    let s = im2col_shape(in_shape, geo.kh, geo.kw, geo.stride, geo.pad_h, geo.pad_w);
    let cands = candidate_tiles(heuristic, s.rows, budget);

    if budget.repeats == 0 {
        // Cost-model mode: pure function of the layer — iterate the
        // sorted ladder and keep the first strict minimum, so ties go to
        // the smaller tile (less scratch for the same traffic).
        let k_full = geo.groups * packed.k_len();
        let mut best = (f64::INFINITY, heuristic);
        let mut scored = 0;
        for &t in &cands {
            let c = tile_cost(t, k_full, packed.total_taps(), s.rows);
            scored += 1;
            if c < best.0 {
                best = (c, t);
            }
        }
        return TileDecision {
            layer: layer.to_string(),
            tile_rows: best.1,
            source: TileSource::Autotuned,
            score: best.0,
            candidates: scored,
        };
    }

    // Measured mode: time the real layer on a seeded synthetic input.
    let sb = budget.sample_batch.clamp(1, in_shape[0]);
    let xshape = [sb, in_shape[1], in_shape[2], in_shape[3]];
    let n: usize = xshape.iter().product();
    let mut rng = Rng::seed_from_u64(budget.seed);
    let xd = rng.vec_range(n, -1.0, 1.0);
    let mut out = Vec::new();
    let mut best: Option<(f64, usize)> = None;
    let mut scored = 0;
    for &t in &cands {
        // one untimed pass grows engine scratch for this tile
        if engine
            .forward_packed_tiled_slice_into(packed, bias, geo, &xd, &xshape, Some(t), &mut out)
            .is_err()
        {
            continue;
        }
        let mut best_ns = f64::INFINITY;
        for _ in 0..budget.repeats {
            let t0 = Instant::now();
            let _ = engine
                .forward_packed_tiled_slice_into(packed, bias, geo, &xd, &xshape, Some(t), &mut out);
            best_ns = best_ns.min(t0.elapsed().as_nanos() as f64);
        }
        scored += 1;
        // strict < keeps the first (smallest) tile on exact ties
        if best.map_or(true, |(b, _)| best_ns < b) {
            best = Some((best_ns, t));
        }
    }
    match best {
        Some((ns, t)) => TileDecision {
            layer: layer.to_string(),
            tile_rows: t,
            source: TileSource::Autotuned,
            score: ns,
            candidates: scored,
        },
        None => fallback(0.0),
    }
}

/// Recorded tile decisions, loaded from a `BENCH_8.json`-style
/// trajectory written by [`JsonReport`] — lets a rerun warm-start from
/// the previous run's sweep instead of re-measuring.
/// `scripts/check.sh --smoke` wires the previous trajectory in through
/// `SUBACCEL_AUTOTUNE_CACHE`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TileCache {
    entries: HashMap<String, usize>,
}

impl TileCache {
    /// Trajectory entry name for one plan step:
    /// `autotune:<plan>:<layer>`.
    pub fn key(plan: &str, layer: &str) -> String {
        format!("autotune:{plan}:{layer}")
    }

    /// Parse every `autotune:*` entry out of a trajectory file. Entries
    /// without a positive integer `tile_rows` are skipped, not errors —
    /// the cache is an accelerant, never a requirement.
    pub fn load(path: &str) -> std::io::Result<Self> {
        let body = std::fs::read_to_string(path)?;
        let mut cache = Self::default();
        for line in body.lines() {
            let Some(name) = entry_name(line) else { continue };
            if !name.starts_with("autotune:") {
                continue;
            }
            let Some(tile) = json_field_f64(line, "tile_rows") else { continue };
            if tile >= 1.0 && tile.fract() == 0.0 {
                cache.entries.insert(name.to_string(), tile as usize);
            }
        }
        Ok(cache)
    }

    /// Cache from the `SUBACCEL_AUTOTUNE_CACHE` env var, when set and
    /// readable; `None` otherwise (unset, missing file — never an
    /// error).
    pub fn from_env() -> Option<Self> {
        let path = std::env::var("SUBACCEL_AUTOTUNE_CACHE").ok()?;
        Self::load(&path).ok()
    }

    /// Record a decision directly (tests, or callers that sweep without
    /// a trajectory file).
    pub fn insert(&mut self, key: impl Into<String>, tile_rows: usize) {
        assert!(tile_rows >= 1, "row tile must be at least 1");
        self.entries.insert(key.into(), tile_rows);
    }

    pub fn get(&self, key: &str) -> Option<usize> {
        self.entries.get(key).copied()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Append every decision to a [`JsonReport`] under its
    /// [`TileCache::key`] name — the persistence half of the warm-start
    /// loop.
    pub fn record(report: &mut JsonReport, plan: &str, decisions: &[TileDecision]) {
        for d in decisions {
            report.push_fields(
                &Self::key(plan, &d.layer),
                &[
                    ("tile_rows", d.tile_rows as f64),
                    ("score", d.score),
                    ("candidates", d.candidates as f64),
                ],
                &[("source", d.source.as_str())],
            );
        }
    }
}

/// Extract the `name` field of one flat trajectory entry. The names this
/// module writes never contain escapes, so a plain quote scan suffices.
fn entry_name(line: &str) -> Option<&str> {
    let k = "\"name\":\"";
    let i = line.find(k)? + k.len();
    let rest = &line[i..];
    Some(&rest[..rest.find('"')?])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::LayerPairing;
    use crate::tensor::Tensor;
    use crate::util::TempDir;

    fn small_layer(rounding: f32) -> (PackedPairing, Tensor, ConvGeometry) {
        let mut rng = Rng::seed_from_u64(11);
        let w = Tensor::new(&[4, 3, 3, 3], rng.vec_range(4 * 27, -1.0, 1.0));
        let b = Tensor::new(&[4], rng.vec_range(4, -1.0, 1.0));
        let p = PackedPairing::from_layer(&LayerPairing::from_weights(&w, rounding));
        (p, b, ConvGeometry::valid(3, 3))
    }

    #[test]
    fn candidate_ladder_is_seeded_clamped_and_bounded() {
        let budget = AutotuneBudget::default();
        let c = candidate_tiles(16, 1000, &budget);
        assert_eq!(c, vec![4, 8, 16, 32, 64]);
        assert!(c.len() <= budget.candidates);
        // the row cap collapses the top of the ladder
        let c = candidate_tiles(16, 20, &budget);
        assert_eq!(c, vec![4, 8, 16, 20]);
        // a tiny seed never produces zero
        let c = candidate_tiles(1, 8, &budget);
        assert!(c.iter().all(|&t| t >= 1));
        assert!(c.contains(&1));
        // truncation keeps the seed in the running
        let tight = AutotuneBudget { candidates: 2, ..AutotuneBudget::default() };
        let c = candidate_tiles(16, 1000, &tight);
        assert_eq!(c.len(), 2);
        assert!(c.contains(&16), "{c:?}");
    }

    #[test]
    fn cost_model_sweep_is_deterministic() {
        let (p, b, geo) = small_layer(0.1);
        let eng = ConvEngine::serial();
        let budget = AutotuneBudget::default();
        let d1 = autotune_conv(&eng, &p, b.data(), geo, &[2, 3, 12, 12], "c", &budget);
        let d2 = autotune_conv(&eng, &p, b.data(), geo, &[2, 3, 12, 12], "c", &budget);
        assert_eq!(d1, d2);
        assert_eq!(d1.source, TileSource::Autotuned);
        assert!(d1.tile_rows >= 1 && d1.candidates >= 1);
        // independent of the engine's thread count (no clocks read)
        let eng4 = ConvEngine::new(4).unwrap();
        let d4 = autotune_conv(&eng4, &p, b.data(), geo, &[2, 3, 12, 12], "c", &budget);
        assert_eq!(d1, d4);
    }

    #[test]
    fn engine_override_short_circuits_the_sweep() {
        let (p, b, geo) = small_layer(0.1);
        let eng = ConvEngine::with_tile_rows(1, 9).unwrap();
        let d = autotune_conv(&eng, &p, b.data(), geo, &[1, 3, 8, 8], "c", &AutotuneBudget::default());
        assert_eq!(d.tile_rows, 9);
        assert_eq!(d.source, TileSource::Override);
        assert_eq!(d.candidates, 0);
    }

    #[test]
    fn degenerate_geometry_falls_back_to_heuristic() {
        let (p, b, geo) = small_layer(0.1);
        let eng = ConvEngine::serial();
        let budget = AutotuneBudget::default();
        // wrong rank, zero dim, kernel larger than input, channel mismatch
        for shape in [&[2usize, 3, 12][..], &[0, 3, 12, 12], &[1, 3, 2, 2], &[1, 5, 12, 12]] {
            let d = autotune_conv(&eng, &p, b.data(), geo, shape, "c", &budget);
            assert_eq!(d.source, TileSource::Heuristic, "{shape:?}");
            assert_eq!(
                d.tile_rows,
                tile_rows_heuristic(p.k_len(), p.cout(), p.total_taps()),
                "{shape:?}"
            );
        }
    }

    #[test]
    fn measured_sweep_picks_a_candidate() {
        let (p, b, geo) = small_layer(0.1);
        let eng = ConvEngine::serial();
        let budget = AutotuneBudget::measured(1);
        let d = autotune_conv(&eng, &p, b.data(), geo, &[2, 3, 12, 12], "c", &budget);
        assert_eq!(d.source, TileSource::Autotuned);
        assert!(d.candidates >= 1);
        assert!(d.score.is_finite() && d.score > 0.0);
        let h = tile_rows_heuristic(p.k_len(), p.cout(), p.total_taps());
        assert!(candidate_tiles(h, 2 * 10 * 10, &budget).contains(&d.tile_rows));
    }

    #[test]
    fn tile_cache_round_trips_through_a_trajectory() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("traj.json");
        let p = path.to_string_lossy().to_string();
        let mut rep = JsonReport::to_path(&p);
        let decisions = vec![
            TileDecision {
                layer: "c1".into(),
                tile_rows: 12,
                source: TileSource::Autotuned,
                score: 3.5e6,
                candidates: 5,
            },
            TileDecision {
                layer: "c3".into(),
                tile_rows: 40,
                source: TileSource::WarmStart,
                score: 0.0,
                candidates: 0,
            },
        ];
        TileCache::record(&mut rep, "lenet5", &decisions);
        rep.finish().unwrap();
        let cache = TileCache::load(&p).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.get(&TileCache::key("lenet5", "c1")), Some(12));
        assert_eq!(cache.get(&TileCache::key("lenet5", "c3")), Some(40));
        assert_eq!(cache.get(&TileCache::key("lenet5", "c5")), None);
        // missing files are io errors, not panics
        assert!(TileCache::load("/nonexistent/traj.json").is_err());
    }

    #[test]
    fn tile_cache_skips_malformed_entries() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("traj.json");
        std::fs::write(
            &path,
            concat!(
                "[\n",
                "  {\"name\":\"alexconv2 steal\",\"ns_per_iter\":123},\n",
                "  {\"name\":\"autotune:p:ok\",\"tile_rows\":8},\n",
                "  {\"name\":\"autotune:p:zero\",\"tile_rows\":0},\n",
                "  {\"name\":\"autotune:p:frac\",\"tile_rows\":2.5},\n",
                "  {\"name\":\"autotune:p:missing\",\"score\":9}\n",
                "]\n"
            ),
        )
        .unwrap();
        let cache = TileCache::load(&path.to_string_lossy()).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.get("autotune:p:ok"), Some(8));
    }
}
