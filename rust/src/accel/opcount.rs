//! Table-1 accounting: run Algorithm 1 over every conv layer of a model
//! and tally additions / subtractions / multiplications per inference for
//! a sweep of rounding sizes. This module *regenerates the paper's
//! Table 1 and Fig 7* (via `benches/table1_opcounts.rs` and the CLI).

use super::preprocess::LayerPairing;
use crate::nn::{Model, OpCounts};

/// The rounding sizes of the paper's Table 1.
pub const TABLE1_ROUNDINGS: [f32; 13] = [
    0.0, 0.0001, 0.005, 0.01, 0.015, 0.02, 0.025, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3,
];

/// One row of the reproduced Table 1.
#[derive(Debug, Clone)]
pub struct ModelOps {
    pub rounding: f32,
    pub adds: u64,
    pub subs: u64,
    pub muls: u64,
    pub total: u64,
    /// Per-layer `(name, pairs, weights)` detail.
    pub layers: Vec<(String, u64, u64)>,
}

/// Conv-layer op counts for one rounding size (Table-1 semantics: conv
/// layers only, one inference, MAC = 1 mul + 1 add, bias excluded).
pub fn model_ops(model: &Model, input_shape: &[usize], rounding: f32) -> ModelOps {
    let mut total = OpCounts::default();
    let mut layers = Vec::new();
    for info in model.conv_layers(input_shape) {
        let pairing = LayerPairing::from_weights(&info.weight, rounding);
        let pairs = pairing.total_pairs() as u64;
        let weights = info.weight.len() as u64;
        let unpaired = weights - 2 * pairs;
        total += OpCounts::paired_layer(pairs, unpaired, info.out_positions as u64, 0);
        layers.push((info.name, pairs, weights));
    }
    ModelOps {
        rounding,
        adds: total.adds,
        subs: total.subs,
        muls: total.muls,
        total: total.table1_total(),
        layers,
    }
}

/// Full Table-1 sweep.
pub fn model_op_sweep(model: &Model, input_shape: &[usize], roundings: &[f32]) -> Vec<ModelOps> {
    roundings.iter().map(|&r| model_ops(model, input_shape, r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::lenet5;

    #[test]
    fn rounding_zero_row_matches_paper_exactly() {
        // Table 1, row 0: 405600 adds, 0 subs, 405600 muls, 811200 total.
        let row = model_ops(&lenet5(), &[1, 1, 32, 32], 0.0);
        assert_eq!(row.adds, 405_600);
        assert_eq!(row.subs, 0);
        assert_eq!(row.muls, 405_600);
        assert_eq!(row.total, 811_200);
    }

    #[test]
    fn table1_identities_hold_for_all_rows() {
        let rows = model_op_sweep(&lenet5(), &[1, 1, 32, 32], &TABLE1_ROUNDINGS);
        for row in &rows {
            assert_eq!(row.adds, row.muls, "rounding {}", row.rounding);
            assert_eq!(row.adds + row.subs, 405_600);
            assert_eq!(row.total, 811_200 - row.subs);
        }
    }

    #[test]
    fn sweep_is_monotone() {
        let rows = model_op_sweep(&lenet5(), &[1, 1, 32, 32], &TABLE1_ROUNDINGS);
        for w in rows.windows(2) {
            assert!(w[1].subs >= w[0].subs);
            assert!(w[1].total <= w[0].total);
        }
    }

    #[test]
    fn grouped_nonsquare_geometry_counts() {
        use crate::nn::grouped_mixer;
        // Grouped weights are simply shorter flat filters, so the paired
        // accounting identities carry over unchanged: gconv1 is
        // 16·(8/2)·3·5 = 960 weights over 20·16 positions, gconv2
        // 32·(16/4)·5·3 = 1920 over 5·4.
        let base = 960u64 * 320 + 1920 * 20;
        for r in [0.0f32, 0.1, 0.3] {
            let row = model_ops(&grouped_mixer(), &[1, 8, 20, 16], r);
            assert_eq!(row.adds, row.muls, "rounding {r}");
            assert_eq!(row.adds + row.subs, base, "rounding {r}");
            assert_eq!(row.total, 2 * base - row.subs, "rounding {r}");
        }
        let row = model_ops(&grouped_mixer(), &[1, 8, 20, 16], 0.1);
        assert_eq!(row.layers.len(), 2);
        assert_eq!(row.layers[0].2, 960);
        assert_eq!(row.layers[1].2, 1920);
    }

    #[test]
    fn per_layer_detail_sums() {
        let row = model_ops(&lenet5(), &[1, 1, 32, 32], 0.1);
        assert_eq!(row.layers.len(), 3);
        let weights: u64 = row.layers.iter().map(|(_, _, w)| w).sum();
        assert_eq!(weights, 150 + 2400 + 48_000);
    }
}
