//! The paper's contribution: weight preprocessing (Section III-A,
//! Algorithm 1) and the modified convolution unit (Section III-B).
//!
//! * [`preprocess`] — sort → split ± → two-pointer combine within a
//!   rounding size; produces [`FilterPairing`]s / [`LayerPairing`]s and
//!   snapped ("modified") weight tensors.
//! * [`subconv`] — executes convolution on the paired representation:
//!   combined weights go through the subtractor lane (`k·(I1−I2)`),
//!   uncombined weights through the ordinary MAC lane. Numerically
//!   identical to dense conv with modified weights (unit + prop tested).
//! * [`engine`] — the execution engine behind [`subconv`]: the
//!   structure-of-arrays [`PackedPairing`] layout and the multi-threaded
//!   [`ConvEngine`] worker pool, running a tile-blocked microkernel fed
//!   by streaming im2col strips over a work-stealing [`ChunkQueue`]
//!   (zero steady-state allocation; bit-identical across thread counts
//!   and tile sizes).
//! * [`autotune`] — one-shot bounded row-tile sweep run at plan-warm
//!   time: picks each conv layer's tile from measured candidates (or a
//!   deterministic cost model), honours the engine's
//!   `SUBACCEL_TILE_ROWS`/`with_tile_rows` hard overrides, and
//!   warm-starts from decisions persisted in the bench trajectory.
//! * [`opcount`] — Table-1 accounting over a whole model for a rounding
//!   sweep.
//! * [`stats`] — weight-distribution statistics (Fig 3 / Fig 4).

mod ablation;
pub mod autotune;
mod engine;
mod opcount;
mod preprocess;
mod stats;
mod subconv;

pub use ablation::{pair_filter_closest_first, total_snap_error};
pub use autotune::{
    autotune_conv, candidate_tiles, AutotuneBudget, TileCache, TileDecision, TileSource,
};
pub use engine::{
    steal_chunk_rows, tile_rows_heuristic, ChunkQueue, ConvEngine, ConvGeometry, ConvOutShape,
    PackedPairing, PaddedTables,
};
pub use opcount::{model_op_sweep, model_ops, ModelOps, TABLE1_ROUNDINGS};
pub use preprocess::{pair_filter, FilterPairing, LayerPairing, WeightClass};
pub use stats::{histogram, Histogram, WeightStats};
pub use subconv::SubConv2d;
