//! Weight-distribution statistics — reproduces the paper's motivation
//! figures: Fig 3 (weight values of LeNet-5's third conv layer) and
//! Fig 4 (their histogram). The paper's argument rests on the near-
//! symmetry of the trained distribution around zero; [`WeightStats`]
//! quantifies it.


/// Histogram over a symmetric range.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn bin_width(&self) -> f32 {
        (self.hi - self.lo) / self.counts.len() as f32
    }

    /// Render one text row per bin: `[lo, hi)  count  ###…` (CLI output).
    pub fn render(&self, max_width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut s = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let lo = self.lo + self.bin_width() * i as f32;
            let bar = "#".repeat((c as usize * max_width) / max as usize);
            s.push_str(&format!("{:>8.3} .. {:>8.3} {:>8} {}\n", lo, lo + self.bin_width(), c, bar));
        }
        s
    }
}

/// Build a histogram of `values` over `[lo, hi)` with `bins` bins; values
/// outside the range clamp into the end bins.
pub fn histogram(values: &[f32], lo: f32, hi: f32, bins: usize) -> Histogram {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0u64; bins];
    let w = (hi - lo) / bins as f32;
    for &v in values {
        let idx = (((v - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        counts[idx] += 1;
    }
    Histogram { lo, hi, counts }
}

/// Symmetry / pairability statistics of a weight distribution.
#[derive(Debug, Clone)]
pub struct WeightStats {
    pub n: usize,
    pub n_pos: usize,
    pub n_neg: usize,
    pub n_zero: usize,
    pub mean: f32,
    pub std: f32,
    pub min: f32,
    pub max: f32,
    /// min(n_pos, n_neg) / (n/2) — upper bound on the pairable fraction.
    pub max_pairable_frac: f32,
}

impl WeightStats {
    pub fn compute(values: &[f32]) -> Self {
        let n = values.len();
        assert!(n > 0, "empty weight slice");
        let n_pos = values.iter().filter(|&&v| v > 0.0).count();
        let n_neg = values.iter().filter(|&&v| v < 0.0).count();
        let n_zero = n - n_pos - n_neg;
        let mean = values.iter().sum::<f32>() / n as f32;
        let var = values.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
        let min = values.iter().copied().fold(f32::INFINITY, f32::min);
        let max = values.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        WeightStats {
            n,
            n_pos,
            n_neg,
            n_zero,
            mean,
            std: var.sqrt(),
            min,
            max,
            max_pairable_frac: n_pos.min(n_neg) as f32 / (n as f32 / 2.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bins() {
        // bins: [-1,-.5) [-.5,0) [0,.5) [.5,1) → -1→0, -0.5→1, 0→2, {0.5,0.99}→3
        let h = histogram(&[-1.0, -0.5, 0.0, 0.5, 0.99], -1.0, 1.0, 4);
        assert_eq!(h.counts, vec![1, 1, 1, 2]);
        assert!((h.bin_width() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let h = histogram(&[-99.0, 99.0], -1.0, 1.0, 2);
        assert_eq!(h.counts, vec![1, 1]);
    }

    #[test]
    fn stats_symmetric_distribution() {
        let vals: Vec<f32> = (1..=50).flat_map(|i| [i as f32 / 50.0, -(i as f32) / 50.0]).collect();
        let s = WeightStats::compute(&vals);
        assert_eq!(s.n_pos, 50);
        assert_eq!(s.n_neg, 50);
        assert!((s.mean).abs() < 1e-6);
        assert!((s.max_pairable_frac - 1.0).abs() < 1e-6);
    }

    #[test]
    fn stats_skewed_distribution() {
        let vals = [1.0f32, 2.0, 3.0, -1.0];
        let s = WeightStats::compute(&vals);
        assert_eq!(s.n_pos, 3);
        assert_eq!(s.n_neg, 1);
        assert!((s.max_pairable_frac - 0.5).abs() < 1e-6);
    }

    #[test]
    fn render_is_line_per_bin() {
        let h = histogram(&[0.1, 0.2], 0.0, 1.0, 5);
        assert_eq!(h.render(10).lines().count(), 5);
    }
}
