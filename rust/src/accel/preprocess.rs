//! Algorithm 1 — the weight preprocessor.
//!
//! Per conv filter (output channel): sort the weights, split into a
//! positive and a negative list (paper Fig 6), then walk both lists in
//! ascending magnitude with two pointers `PP` / `PN`:
//!
//! ```text
//! PP.val ≥ |PN.val| + rounding  →  negative too small: mark uncombined, ++PN
//! PP.val ≤ |PN.val| − rounding  →  positive too small: mark uncombined, ++PP
//! otherwise                     →  combine, ++PP, ++PN
//! ```
//!
//! A combined pair `(Ka, Kb)` is snapped to the mean magnitude
//! `k = (Ka + |Kb|)/2` so `Kb = −Ka` holds exactly and inference may use
//! `k · (I1 − I2)` (paper eq. 1). Per-weight error ≤ `rounding / 2`.
//!
//! Cross-validated against the numpy reference
//! (`python/compile/preprocess.py`) through shared artifacts, and
//! property-tested in `rust/tests/prop_preprocess.rs`.

use crate::tensor::Tensor;

/// Status of one weight after preprocessing (the paper's `U` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightClass {
    /// Combined into a subtractor pair.
    Combined,
    /// Left on the ordinary MAC path.
    Uncombined,
}

/// Pairing of one filter's weights (flat indices into the filter).
#[derive(Debug, Clone, Default)]
pub struct FilterPairing {
    /// Flat index of the positive member of each pair.
    pub pair_i1: Vec<u32>,
    /// Flat index of the negative member of each pair.
    pub pair_i2: Vec<u32>,
    /// Snapped magnitude `k` of each pair.
    pub pair_k: Vec<f32>,
    /// Flat indices of uncombined weights.
    pub unp_idx: Vec<u32>,
    /// Values of uncombined weights (unchanged).
    pub unp_w: Vec<f32>,
}

impl FilterPairing {
    pub fn n_pairs(&self) -> usize {
        self.pair_k.len()
    }

    pub fn n_unpaired(&self) -> usize {
        self.unp_w.len()
    }

    /// Per-weight status vector (for the paper's flag bookkeeping).
    pub fn classes(&self, k_len: usize) -> Vec<WeightClass> {
        let mut c = vec![WeightClass::Uncombined; k_len];
        for &i in self.pair_i1.iter().chain(&self.pair_i2) {
            c[i as usize] = WeightClass::Combined;
        }
        c
    }
}

/// Run Algorithm 1 on one flattened filter.
pub fn pair_filter(w: &[f32], rounding: f32) -> FilterPairing {
    let mut res = FilterPairing::default();
    // sort + split (paper Fig 6); ascending magnitude for both lists
    let mut pos: Vec<(f32, u32)> = Vec::new();
    let mut neg: Vec<(f32, u32)> = Vec::new();
    for (i, &v) in w.iter().enumerate() {
        if v > 0.0 {
            pos.push((v, i as u32));
        } else if v < 0.0 {
            neg.push((v, i as u32));
        } else {
            res.unp_idx.push(i as u32);
            res.unp_w.push(v);
        }
    }
    pos.sort_by(|a, b| a.0.total_cmp(&b.0));
    neg.sort_by(|a, b| b.0.total_cmp(&a.0)); // -0.1 before -0.9

    let (mut pp, mut pn) = (0usize, 0usize);
    while pp < pos.len() && pn < neg.len() {
        let (pv, pi) = pos[pp];
        let (nv, ni) = neg[pn];
        let nmag = -nv;
        if pv >= nmag + rounding {
            // negative weight too small — no future positive will be closer
            res.unp_idx.push(ni);
            res.unp_w.push(nv);
            pn += 1;
        } else if pv <= nmag - rounding {
            // positive weight too small
            res.unp_idx.push(pi);
            res.unp_w.push(pv);
            pp += 1;
        } else {
            res.pair_i1.push(pi);
            res.pair_i2.push(ni);
            res.pair_k.push((pv + nmag) / 2.0);
            pp += 1;
            pn += 1;
        }
    }
    for &(v, i) in &pos[pp..] {
        res.unp_idx.push(i);
        res.unp_w.push(v);
    }
    for &(v, i) in &neg[pn..] {
        res.unp_idx.push(i);
        res.unp_w.push(v);
    }
    res
}

/// Pairing of a whole conv layer `(Cout, Cin/groups, kh, kw)` — grouped
/// weights work unchanged, since Algorithm 1 runs per filter and a
/// grouped filter is just a shorter flat weight vector.
#[derive(Debug, Clone)]
pub struct LayerPairing {
    pub filters: Vec<FilterPairing>,
    /// Flat weights-per-filter (`Cin/groups · kh · kw`; the engine calls
    /// this the per-group patch length).
    pub k_len: usize,
    /// Weight tensor shape this pairing was derived from.
    pub shape: Vec<usize>,
    /// Rounding size used.
    pub rounding: f32,
}

impl LayerPairing {
    /// Run Algorithm 1 over every filter of a conv weight tensor.
    pub fn from_weights(w: &Tensor, rounding: f32) -> Self {
        assert!(w.ndim() >= 2, "conv weights must be at least 2-D");
        assert!(rounding >= 0.0, "rounding must be non-negative");
        let cout = w.shape()[0];
        let k_len: usize = w.shape()[1..].iter().product();
        let filters = (0..cout)
            .map(|c| pair_filter(&w.data()[c * k_len..(c + 1) * k_len], rounding))
            .collect();
        Self { filters, k_len, shape: w.shape().to_vec(), rounding }
    }

    /// Total combined pairs across all filters.
    pub fn total_pairs(&self) -> usize {
        self.filters.iter().map(FilterPairing::n_pairs).sum()
    }

    /// Snapped ("modified") weight tensor: dense conv with this tensor is
    /// numerically identical to the paired computation.
    pub fn modified_weights(&self, w: &Tensor) -> Tensor {
        assert_eq!(w.shape(), self.shape.as_slice(), "pairing/weights mismatch");
        let mut out = w.clone();
        let data = out.data_mut();
        for (c, f) in self.filters.iter().enumerate() {
            let base = c * self.k_len;
            for j in 0..f.n_pairs() {
                data[base + f.pair_i1[j] as usize] = f.pair_k[j];
                data[base + f.pair_i2[j] as usize] = -f.pair_k[j];
            }
        }
        out
    }

    /// Maximum per-weight snap error (must be ≤ rounding/2).
    pub fn max_snap_error(&self, w: &Tensor) -> f32 {
        self.modified_weights(w).max_abs_diff(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_opposites_pair_with_zero_error() {
        let w = [0.5f32, -0.5, 0.25, -0.25];
        let p = pair_filter(&w, 0.01);
        assert_eq!(p.n_pairs(), 2);
        assert_eq!(p.n_unpaired(), 0);
        // smallest magnitudes pair first
        assert_eq!(p.pair_k, vec![0.25, 0.5]);
        assert_eq!((p.pair_i1[0], p.pair_i2[0]), (2, 3));
        assert_eq!((p.pair_i1[1], p.pair_i2[1]), (0, 1));
    }

    #[test]
    fn rounding_zero_pairs_nothing_random() {
        let w = [0.5f32, -0.5000001, 0.3, -0.2];
        let p = pair_filter(&w, 0.0);
        assert_eq!(p.n_pairs(), 0);
        assert_eq!(p.n_unpaired(), 4);
    }

    #[test]
    fn boundary_is_exclusive() {
        // gap exactly == rounding → the ≥ / ≤ conditions fire, no pair
        let p = pair_filter(&[0.5, -0.4], 0.1);
        assert_eq!(p.n_pairs(), 0);
        let p = pair_filter(&[0.5, -0.4], 0.100001);
        assert_eq!(p.n_pairs(), 1);
        assert!((p.pair_k[0] - 0.45).abs() < 1e-6);
    }

    #[test]
    fn zeros_are_uncombined() {
        let p = pair_filter(&[0.0, 0.3, -0.3, 0.0], 0.05);
        assert_eq!(p.n_pairs(), 1);
        assert_eq!(p.n_unpaired(), 2);
        assert!(p.unp_w.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn snap_error_bounded() {
        let w: Vec<f32> = (0..100)
            .map(|i| ((i * 2654435761u64 as usize) % 1000) as f32 / 500.0 - 1.0)
            .collect();
        let t = Tensor::new(&[4, 25], w);
        for r in [0.01f32, 0.05, 0.2, 1.0] {
            let p = LayerPairing::from_weights(&t, r);
            assert!(
                p.max_snap_error(&t) <= r / 2.0 + 1e-6,
                "rounding {r}: err {}",
                p.max_snap_error(&t)
            );
        }
    }

    #[test]
    fn conservation_no_weight_lost() {
        let w: Vec<f32> = (0..60).map(|i| (i as f32 - 30.0) / 17.0).collect();
        let p = pair_filter(&w, 0.2);
        assert_eq!(2 * p.n_pairs() + p.n_unpaired(), 60);
        let mut seen: Vec<u32> = p
            .pair_i1
            .iter()
            .chain(&p.pair_i2)
            .chain(&p.unp_idx)
            .copied()
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..60).collect::<Vec<_>>());
    }

    #[test]
    fn classes_flagging() {
        let p = pair_filter(&[0.5, -0.5, 0.1], 0.01);
        let c = p.classes(3);
        assert_eq!(c[0], WeightClass::Combined);
        assert_eq!(c[1], WeightClass::Combined);
        assert_eq!(c[2], WeightClass::Uncombined);
    }

    #[test]
    fn monotone_pairs_in_rounding() {
        let w: Vec<f32> = (0..80).map(|i| ((i * 37) % 100) as f32 / 50.0 - 1.0).collect();
        let mut prev = 0;
        for r in [0.0f32, 0.01, 0.05, 0.1, 0.5, 2.0] {
            let n = pair_filter(&w, r).n_pairs();
            assert!(n >= prev, "rounding {r}: {n} < {prev}");
            prev = n;
        }
    }

    #[test]
    fn layer_pairing_modified_weights() {
        let t = Tensor::new(&[1, 4], vec![0.5, -0.52, 0.1, -0.9]);
        let p = LayerPairing::from_weights(&t, 0.05);
        assert_eq!(p.total_pairs(), 1);
        let m = p.modified_weights(&t);
        assert!((m.data()[0] - 0.51).abs() < 1e-6);
        assert!((m.data()[1] + 0.51).abs() < 1e-6);
        assert_eq!(m.data()[2], 0.1);
        assert_eq!(m.data()[3], -0.9);
    }
}
