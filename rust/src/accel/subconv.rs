//! The modified convolution unit (paper Section III-B, Fig 5).
//!
//! Executes a conv layer on the *paired* weight representation: each
//! combined pair computes `k · (I1 − I2)` — one subtraction replacing one
//! multiply + one add — and each uncombined weight takes the ordinary
//! multiply-accumulate lane. Exact op accounting comes out with the
//! result; numerics are identical to dense conv with the snapped weights
//! (verified against [`crate::nn::layers::conv2d`] in tests and against
//! the Pallas artifact in the integration suite).
//!
//! Execution is delegated to [`ConvEngine`]: a compiled layer carries a
//! [`PackedPairing`] (structure-of-arrays layout, built once), and
//! [`SubConv2d::forward`] runs it on a process-wide serial engine.
//! Callers that want multi-core or buffer reuse pass their own engine
//! via [`SubConv2d::forward_with`].

use std::sync::OnceLock;

use super::engine::{ConvEngine, ConvGeometry, PackedPairing};
use super::preprocess::LayerPairing;
use crate::error::SubaccelError;
use crate::nn::OpCounts;
use crate::tensor::Tensor;

/// A conv layer compiled to the subtractor representation.
#[derive(Debug, Clone)]
pub struct SubConv2d {
    pairing: LayerPairing,
    packed: PackedPairing,
    bias: Tensor,
    geo: ConvGeometry,
}

/// Process-wide single-threaded engine backing the plain
/// [`SubConv2d::forward`], so the historical no-handle API keeps
/// working without per-call engine setup.
fn serial_engine() -> &'static ConvEngine {
    static ENGINE: OnceLock<ConvEngine> = OnceLock::new();
    ENGINE.get_or_init(ConvEngine::serial)
}

impl SubConv2d {
    /// Preprocess a dense conv layer (`weight (Cout, Cin, kh, kw)`,
    /// `bias (Cout,)`) at the given rounding size. Valid conv, stride 1.
    pub fn compile(weight: &Tensor, bias: &Tensor, rounding: f32) -> Self {
        Self::compile_geo(weight, bias, rounding, 1, 0)
    }

    /// [`SubConv2d::compile`] with explicit stride / symmetric zero
    /// padding (AlexNet-style geometries). Panics on malformed inputs
    /// (historical API); grouped or asymmetric layers go through the
    /// typed [`SubConv2d::compile_with`].
    pub fn compile_geo(
        weight: &Tensor,
        bias: &Tensor,
        rounding: f32,
        stride: usize,
        pad: usize,
    ) -> Self {
        let (kh, kw) = (weight.shape()[2], weight.shape()[3]);
        Self::compile_with(weight, bias, rounding, ConvGeometry::symmetric(kh, kw, stride, pad))
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Compile against a full [`ConvGeometry`] — groups, non-square
    /// kernels, asymmetric padding — with every malformed combination
    /// reported as a typed [`SubaccelError::InvalidConfig`] instead of a
    /// panic. For grouped layers the weight is the standard grouped OIHW
    /// `(Cout, Cin/groups, kh, kw)`.
    pub fn compile_with(
        weight: &Tensor,
        bias: &Tensor,
        rounding: f32,
        geo: ConvGeometry,
    ) -> Result<Self, SubaccelError> {
        let bad = |field: &'static str, reason: String| SubaccelError::InvalidConfig {
            field,
            reason,
        };
        if weight.ndim() != 4 {
            return Err(bad("weight", format!("conv weight must be OIHW, got {:?}", weight.shape())));
        }
        let cout = weight.shape()[0];
        if bias.len() != cout {
            return Err(bad("bias", format!("bias length {} != Cout {cout}", bias.len())));
        }
        if geo.kh != weight.shape()[2] || geo.kw != weight.shape()[3] {
            return Err(bad(
                "kernel",
                format!(
                    "geometry kernel {}x{} != weight kernel {}x{}",
                    geo.kh,
                    geo.kw,
                    weight.shape()[2],
                    weight.shape()[3]
                ),
            ));
        }
        if geo.stride == 0 {
            return Err(bad("stride", "conv stride must be at least 1".into()));
        }
        if geo.groups == 0 {
            return Err(bad("groups", "conv groups must be at least 1".into()));
        }
        if cout % geo.groups != 0 {
            return Err(bad(
                "groups",
                format!("{cout} output channels not divisible into {} groups", geo.groups),
            ));
        }
        let pairing = LayerPairing::from_weights(weight, rounding);
        let packed = PackedPairing::from_layer(&pairing);
        Ok(Self { pairing, packed, bias: bias.clone(), geo })
    }

    /// Wrap an existing pairing (e.g. deserialized from disk).
    pub fn from_pairing(pairing: LayerPairing, bias: Tensor) -> Self {
        let (kh, kw) = (pairing.shape[2], pairing.shape[3]);
        let packed = PackedPairing::from_layer(&pairing);
        Self { pairing, packed, bias, geo: ConvGeometry::valid(kh, kw) }
    }

    pub fn pairing(&self) -> &LayerPairing {
        &self.pairing
    }

    /// The packed (structure-of-arrays) pairing the engine executes.
    pub fn packed(&self) -> &PackedPairing {
        &self.packed
    }

    pub fn geometry(&self) -> ConvGeometry {
        self.geo
    }

    pub fn bias(&self) -> &Tensor {
        &self.bias
    }

    /// Total combined pairs across filters.
    pub fn total_pairs(&self) -> usize {
        self.pairing.total_pairs()
    }

    /// Total uncombined (ordinary MAC lane) taps across filters.
    pub fn total_unpaired(&self) -> usize {
        self.packed.total_unpaired()
    }

    /// Run the layer on an NCHW input using the process-wide serial
    /// engine. Panics on shape mismatch (historical API; use
    /// [`SubConv2d::try_forward`] or [`SubConv2d::forward_with`] for
    /// typed errors).
    pub fn forward(&self, x: &Tensor) -> (Tensor, OpCounts) {
        self.try_forward(x).expect("input channels/kernel mismatch")
    }

    /// [`SubConv2d::forward`] with a typed error instead of a panic.
    pub fn try_forward(&self, x: &Tensor) -> Result<(Tensor, OpCounts), SubaccelError> {
        self.forward_with(serial_engine(), x)
    }

    /// Run the layer on the given engine (multi-core and scratch reuse
    /// are the engine's business).
    pub fn forward_with(
        &self,
        engine: &ConvEngine,
        x: &Tensor,
    ) -> Result<(Tensor, OpCounts), SubaccelError> {
        engine.forward_packed(&self.packed, &self.bias, self.geo, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::conv2d;
    use crate::util::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect())
    }

    #[test]
    fn matches_dense_with_modified_weights() {
        let mut rng = Rng::seed_from_u64(11);
        for &rounding in &[0.0f32, 0.01, 0.05, 0.2, 5.0] {
            let x = rand_t(&mut rng, &[2, 3, 9, 9]);
            let w = rand_t(&mut rng, &[5, 3, 4, 4]);
            let b = rand_t(&mut rng, &[5]);
            let sc = SubConv2d::compile(&w, &b, rounding);
            let (got, counts) = sc.forward(&x);
            let wmod = sc.pairing().modified_weights(&w);
            let (want, base_counts) = conv2d(&x, &wmod, &b, 1, 0);
            assert!(
                got.max_abs_diff(&want) < 1e-5,
                "rounding {rounding}: {}",
                got.max_abs_diff(&want)
            );
            // op identity: subs replaced muls/adds one-for-one
            assert_eq!(counts.muls + counts.subs, base_counts.muls);
            assert_eq!(counts.adds + counts.subs, base_counts.adds);
        }
    }

    #[test]
    fn rounding_zero_is_bit_identical_to_dense() {
        let mut rng = Rng::seed_from_u64(3);
        let x = rand_t(&mut rng, &[1, 2, 6, 6]);
        let w = rand_t(&mut rng, &[3, 2, 3, 3]);
        let b = rand_t(&mut rng, &[3]);
        let sc = SubConv2d::compile(&w, &b, 0.0);
        assert_eq!(sc.total_pairs(), 0);
        let (got, counts) = sc.forward(&x);
        let (want, _) = conv2d(&x, &w, &b, 1, 0);
        // same weights; summation order differs → tiny f32 tolerance
        assert!(got.max_abs_diff(&want) < 1e-5);
        assert_eq!(counts.subs, 0);
    }

    #[test]
    fn lenet_c1_geometry_counts() {
        let mut rng = Rng::seed_from_u64(9);
        let x = rand_t(&mut rng, &[1, 1, 32, 32]);
        let w = rand_t(&mut rng, &[6, 1, 5, 5]);
        let b = Tensor::zeros(&[6]);
        let sc = SubConv2d::compile(&w, &b, 0.1);
        let (y, counts) = sc.forward(&x);
        assert_eq!(y.shape(), &[1, 6, 28, 28]);
        let base = 6 * 25 * 784u64;
        assert_eq!(counts.subs, sc.total_pairs() as u64 * 784);
        assert_eq!(counts.muls, base - counts.subs);
        assert_eq!(counts.adds, counts.muls);
    }

    #[test]
    fn batch_independence() {
        // forwarding a batch == forwarding images separately
        let mut rng = Rng::seed_from_u64(5);
        let x0 = rand_t(&mut rng, &[1, 2, 7, 7]);
        let x1 = rand_t(&mut rng, &[1, 2, 7, 7]);
        let w = rand_t(&mut rng, &[4, 2, 3, 3]);
        let b = rand_t(&mut rng, &[4]);
        let sc = SubConv2d::compile(&w, &b, 0.05);
        let mut xb = x0.data().to_vec();
        xb.extend_from_slice(x1.data());
        let (yb, _) = sc.forward(&Tensor::new(&[2, 2, 7, 7], xb));
        let (y0, _) = sc.forward(&x0);
        let (y1, _) = sc.forward(&x1);
        let half = yb.len() / 2;
        assert_eq!(&yb.data()[..half], y0.data());
        assert_eq!(&yb.data()[half..], y1.data());
    }

    #[test]
    fn strided_padded_matches_dense_modified() {
        let mut rng = Rng::seed_from_u64(17);
        let x = rand_t(&mut rng, &[1, 3, 15, 15]);
        let w = rand_t(&mut rng, &[4, 3, 5, 5]);
        let b = rand_t(&mut rng, &[4]);
        let sc = SubConv2d::compile_geo(&w, &b, 0.1, 2, 2);
        let (got, _) = sc.forward(&x);
        let wmod = sc.pairing().modified_weights(&w);
        let (want, _) = conv2d(&x, &wmod, &b, 2, 2);
        assert_eq!(got.shape(), want.shape());
        assert!(got.max_abs_diff(&want) < 1e-5, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn try_forward_surfaces_typed_mismatch() {
        let mut rng = Rng::seed_from_u64(23);
        let w = rand_t(&mut rng, &[2, 2, 3, 3]);
        let sc = SubConv2d::compile(&w, &Tensor::zeros(&[2]), 0.0);
        let bad = rand_t(&mut rng, &[1, 3, 8, 8]);
        match sc.try_forward(&bad) {
            Err(SubaccelError::KernelMismatch { expected_k: 18, got_k: 27 }) => {}
            other => panic!("expected KernelMismatch, got {other:?}"),
        }
    }
}
