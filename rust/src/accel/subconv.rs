//! The modified convolution unit (paper Section III-B, Fig 5).
//!
//! Executes a conv layer on the *paired* weight representation: each
//! combined pair computes `k · (I1 − I2)` — one subtraction replacing one
//! multiply + one add — and each uncombined weight takes the ordinary
//! multiply-accumulate lane. Exact op accounting comes out with the
//! result; numerics are identical to dense conv with the snapped weights
//! (verified against [`crate::nn::layers::conv2d`] in tests and against
//! the Pallas artifact in the integration suite).

use super::preprocess::LayerPairing;
use crate::nn::OpCounts;
use crate::tensor::{im2col, Tensor};

/// A conv layer compiled to the subtractor representation.
#[derive(Debug, Clone)]
pub struct SubConv2d {
    pairing: LayerPairing,
    bias: Tensor,
    kh: usize,
    kw: usize,
    cout: usize,
}

impl SubConv2d {
    /// Preprocess a dense conv layer (`weight (Cout, Cin, kh, kw)`,
    /// `bias (Cout,)`) at the given rounding size.
    pub fn compile(weight: &Tensor, bias: &Tensor, rounding: f32) -> Self {
        assert_eq!(weight.ndim(), 4, "conv weight must be OIHW");
        let cout = weight.shape()[0];
        assert_eq!(bias.len(), cout, "bias length");
        Self {
            pairing: LayerPairing::from_weights(weight, rounding),
            bias: bias.clone(),
            kh: weight.shape()[2],
            kw: weight.shape()[3],
            cout,
        }
    }

    /// Wrap an existing pairing (e.g. deserialized from disk).
    pub fn from_pairing(pairing: LayerPairing, bias: Tensor) -> Self {
        let cout = pairing.shape[0];
        let (kh, kw) = (pairing.shape[2], pairing.shape[3]);
        Self { pairing, bias, kh, kw, cout }
    }

    pub fn pairing(&self) -> &LayerPairing {
        &self.pairing
    }

    /// Total combined pairs across filters.
    pub fn total_pairs(&self) -> usize {
        self.pairing.total_pairs()
    }

    /// Run the layer on an NCHW input (valid, stride 1 — LeNet geometry).
    ///
    /// Hot path layout: one im2col per layer, then per output position the
    /// pair lane walks `(i1, i2, k)` triples and the MAC lane walks
    /// `(idx, w)` pairs — exactly the schedule the PE array in
    /// [`crate::hw::pe`] models.
    pub fn forward(&self, x: &Tensor) -> (Tensor, OpCounts) {
        let ic = im2col(x, self.kh, self.kw);
        let rows = ic.patches.shape()[0];
        let k = ic.k;
        assert_eq!(k, self.pairing.k_len, "input channels/kernel mismatch");
        let mut out = vec![0f32; rows * self.cout];
        let patches = ic.patches.data();

        // Loop order: rows outer, filters inner (§Perf iteration 3) — each
        // patch is loaded once and stays in L1 across all 16–120 filters.
        for r in 0..rows {
            let patch = &patches[r * k..(r + 1) * k];
            for (c, f) in self.pairing.filters.iter().enumerate() {
                let bias = self.bias.data()[c];
                // subtractor lane: zipped triples avoid per-element bounds
                // checks on the pairing arrays (§Perf iteration 2)
                let pair_acc: f32 = f
                    .pair_i1
                    .iter()
                    .zip(&f.pair_i2)
                    .zip(&f.pair_k)
                    .map(|((&i1, &i2), &kv)| kv * (patch[i1 as usize] - patch[i2 as usize]))
                    .sum();
                // ordinary MAC lane
                let mac_acc: f32 = f
                    .unp_idx
                    .iter()
                    .zip(&f.unp_w)
                    .map(|(&iu, &wv)| wv * patch[iu as usize])
                    .sum();
                out[r * self.cout + c] = bias + pair_acc + mac_acc;
            }
        }

        // (rows, Cout) → (B, Cout, OH, OW)
        let (b, oh, ow) = (ic.batch, ic.out_h, ic.out_w);
        let mut nchw = vec![0f32; out.len()];
        for bi in 0..b {
            for y in 0..oh {
                for xw in 0..ow {
                    let r = (bi * oh + y) * ow + xw;
                    for c in 0..self.cout {
                        nchw[((bi * self.cout + c) * oh + y) * ow + xw] =
                            out[r * self.cout + c];
                    }
                }
            }
        }

        let pairs: u64 = self.pairing.total_pairs() as u64;
        let unpaired: u64 =
            self.pairing.filters.iter().map(|f| f.n_unpaired() as u64).sum();
        let counts = OpCounts::paired_layer(
            pairs,
            unpaired,
            (b * oh * ow) as u64,
            (b * oh * ow * self.cout) as u64,
        );
        (Tensor::new(&[b, self.cout, oh, ow], nchw), counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::conv2d;
    use crate::util::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect())
    }

    #[test]
    fn matches_dense_with_modified_weights() {
        let mut rng = Rng::seed_from_u64(11);
        for &rounding in &[0.0f32, 0.01, 0.05, 0.2, 5.0] {
            let x = rand_t(&mut rng, &[2, 3, 9, 9]);
            let w = rand_t(&mut rng, &[5, 3, 4, 4]);
            let b = rand_t(&mut rng, &[5]);
            let sc = SubConv2d::compile(&w, &b, rounding);
            let (got, counts) = sc.forward(&x);
            let wmod = sc.pairing().modified_weights(&w);
            let (want, base_counts) = conv2d(&x, &wmod, &b, 1, 0);
            assert!(
                got.max_abs_diff(&want) < 1e-5,
                "rounding {rounding}: {}",
                got.max_abs_diff(&want)
            );
            // op identity: subs replaced muls/adds one-for-one
            assert_eq!(counts.muls + counts.subs, base_counts.muls);
            assert_eq!(counts.adds + counts.subs, base_counts.adds);
        }
    }

    #[test]
    fn rounding_zero_is_bit_identical_to_dense() {
        let mut rng = Rng::seed_from_u64(3);
        let x = rand_t(&mut rng, &[1, 2, 6, 6]);
        let w = rand_t(&mut rng, &[3, 2, 3, 3]);
        let b = rand_t(&mut rng, &[3]);
        let sc = SubConv2d::compile(&w, &b, 0.0);
        assert_eq!(sc.total_pairs(), 0);
        let (got, counts) = sc.forward(&x);
        let (want, _) = conv2d(&x, &w, &b, 1, 0);
        // same weights; summation order differs → tiny f32 tolerance
        assert!(got.max_abs_diff(&want) < 1e-5);
        assert_eq!(counts.subs, 0);
    }

    #[test]
    fn lenet_c1_geometry_counts() {
        let mut rng = Rng::seed_from_u64(9);
        let x = rand_t(&mut rng, &[1, 1, 32, 32]);
        let w = rand_t(&mut rng, &[6, 1, 5, 5]);
        let b = Tensor::zeros(&[6]);
        let sc = SubConv2d::compile(&w, &b, 0.1);
        let (y, counts) = sc.forward(&x);
        assert_eq!(y.shape(), &[1, 6, 28, 28]);
        let base = 6 * 25 * 784u64;
        assert_eq!(counts.subs, sc.total_pairs() as u64 * 784);
        assert_eq!(counts.muls, base - counts.subs);
        assert_eq!(counts.adds, counts.muls);
    }

    #[test]
    fn batch_independence() {
        // forwarding a batch == forwarding images separately
        let mut rng = Rng::seed_from_u64(5);
        let x0 = rand_t(&mut rng, &[1, 2, 7, 7]);
        let x1 = rand_t(&mut rng, &[1, 2, 7, 7]);
        let w = rand_t(&mut rng, &[4, 2, 3, 3]);
        let b = rand_t(&mut rng, &[4]);
        let sc = SubConv2d::compile(&w, &b, 0.05);
        let mut xb = x0.data().to_vec();
        xb.extend_from_slice(x1.data());
        let (yb, _) = sc.forward(&Tensor::new(&[2, 2, 7, 7], xb));
        let (y0, _) = sc.forward(&x0);
        let (y1, _) = sc.forward(&x1);
        let half = yb.len() / 2;
        assert_eq!(&yb.data()[..half], y0.data());
        assert_eq!(&yb.data()[half..], y1.data());
    }
}
