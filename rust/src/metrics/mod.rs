//! Serving metrics: latency histograms and throughput counters for the
//! coordinator. Lock-free on the hot path (atomics); the primary read
//! interface is a structured [`MetricsSnapshot`] (fields to assert on or
//! export), with the human-readable one-liner available as its
//! `Display` impl / [`ServerMetrics::summary`].

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-spaced latency histogram from 1 µs to ~17 s.
#[derive(Debug)]
pub struct LatencyHistogram {
    // bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const BUCKETS: usize = 25;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile, `p` in [0, 100]: the *inclusive* upper
    /// bound of the bucket holding the p-th sample (`2^(i+1) − 1` for
    /// bucket `[2^i, 2^(i+1))`), clamped to the observed maximum so no
    /// percentile ever exceeds `max_us`.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (((p / 100.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // the last bucket is open-ended [2^(BUCKETS-1), ∞): its
                // only honest bound is the observed maximum
                if i == BUCKETS - 1 {
                    return self.max_us();
                }
                return ((1u64 << (i + 1)) - 1).min(self.max_us());
            }
        }
        // target ≤ total guarantees the loop matched; a racing reader
        // can still land here — report the observed maximum, not u64::MAX
        self.max_us()
    }

    /// Consistent-enough point-in-time view of this histogram.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            mean_us: self.mean_us(),
            p50_us: self.percentile_us(50.0),
            p99_us: self.percentile_us(99.0),
            max_us: self.max_us(),
        }
    }
}

/// Point-in-time summary of one latency histogram.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

/// Counters for the serving pipeline.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub queue_latency: LatencyHistogram,
    pub e2e_latency: LatencyHistogram,
    pub execute_latency: LatencyHistogram,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// The primary read interface: every counter and histogram as plain
    /// fields. Assert on these (or export them) instead of parsing the
    /// `Display` string.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            mean_batch_size: self.mean_batch_size(),
            queue: self.queue_latency.snapshot(),
            e2e: self.e2e_latency.snapshot(),
            execute: self.execute_latency.snapshot(),
        }
    }

    /// Human-readable one-liner (the snapshot's `Display`).
    pub fn summary(&self) -> String {
        self.snapshot().to_string()
    }
}

/// Structured view of [`ServerMetrics`] at one point in time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub mean_batch_size: f64,
    pub queue: HistogramSnapshot,
    pub e2e: HistogramSnapshot,
    pub execute: HistogramSnapshot,
}

impl fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "requests={} completed={} rejected={} batches={} mean_batch={:.2} \
             e2e_mean={:.0}us e2e_p50={}us e2e_p99={}us exec_mean={:.0}us",
            self.requests,
            self.completed,
            self.rejected,
            self.batches,
            self.mean_batch_size,
            self.e2e.mean_us,
            self.e2e.p50_us,
            self.e2e.p99_us,
            self.execute.mean_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(1000));
        assert_eq!(h.count(), 3);
        assert!((h.mean_us() - 370.0).abs() < 1.0);
        assert_eq!(h.max_us(), 1000);
        assert!(h.percentile_us(100.0) >= 1000);
        assert!(h.percentile_us(1.0) <= 16);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.percentile_us(50.0), 0);
        assert_eq!(h.snapshot(), HistogramSnapshot::default());
    }

    #[test]
    fn zero_duration_clamps() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1));
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_us(), 1);
    }

    #[test]
    fn percentile_reports_own_bucket_bound() {
        // regression: a 1 µs sample used to report 2 µs (the *next*
        // bucket's bound); it must report its own bucket, clamped to max
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(1));
        assert_eq!(h.percentile_us(50.0), 1);
        assert_eq!(h.percentile_us(100.0), 1);

        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(10)); // bucket [8, 16)
        assert_eq!(h.percentile_us(50.0), 10); // 15 clamped to max_us
        h.record(Duration::from_micros(14));
        assert_eq!(h.percentile_us(99.0), 14);
    }

    #[test]
    fn percentile_never_exceeds_max() {
        let h = LatencyHistogram::new();
        // deep into the last bucket (≥ 2^24 µs): no u64::MAX fall-through
        h.record(Duration::from_secs(60));
        assert_eq!(h.percentile_us(99.0), 60_000_000);
        assert!(h.percentile_us(100.0) <= h.max_us());
    }

    #[test]
    fn histogram_snapshot_fields() {
        let h = LatencyHistogram::new();
        for us in [100u64, 200, 400, 800] {
            h.record(Duration::from_micros(us));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert!((s.mean_us - 375.0).abs() < 1.0);
        assert_eq!(s.max_us, 800);
        assert!(s.p50_us >= 200 && s.p50_us <= 255, "p50 {}", s.p50_us);
        assert!(s.p99_us >= 800 && s.p99_us <= s.max_us);
    }

    #[test]
    fn metrics_batch_mean() {
        let m = ServerMetrics::new();
        m.batches.store(2, Ordering::Relaxed);
        m.batched_items.store(9, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 4.5).abs() < 1e-9);
        assert!(m.summary().contains("mean_batch=4.50"));
    }

    #[test]
    fn snapshot_carries_counters_and_displays_like_summary() {
        let m = ServerMetrics::new();
        m.requests.store(10, Ordering::Relaxed);
        m.completed.store(8, Ordering::Relaxed);
        m.rejected.store(2, Ordering::Relaxed);
        m.batches.store(4, Ordering::Relaxed);
        m.batched_items.store(8, Ordering::Relaxed);
        m.e2e_latency.record(Duration::from_micros(100));
        let s = m.snapshot();
        assert_eq!(s.requests, 10);
        assert_eq!(s.completed, 8);
        assert_eq!(s.rejected, 2);
        assert_eq!(s.batches, 4);
        assert_eq!(s.batched_items, 8);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-9);
        assert_eq!(s.e2e.count, 1);
        // summary() is exactly the snapshot's Display
        assert_eq!(m.summary(), s.to_string());
        assert!(s.to_string().starts_with("requests=10 completed=8 rejected=2"));
    }
}
