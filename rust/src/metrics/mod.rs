//! Serving metrics: latency histograms and throughput counters for the
//! coordinator. Lock-free on the hot path (atomics); snapshots are cheap
//! and consistent-enough for reporting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-spaced latency histogram from 1 µs to ~17 s.
#[derive(Debug)]
pub struct LatencyHistogram {
    // bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const BUCKETS: usize = 25;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile (bucket upper bound), `p` in [0, 100].
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }
}

/// Counters for the serving pipeline.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub queue_latency: LatencyHistogram,
    pub e2e_latency: LatencyHistogram,
    pub execute_latency: LatencyHistogram,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} completed={} rejected={} batches={} mean_batch={:.2} \
             e2e_mean={:.0}us e2e_p50={}us e2e_p99={}us exec_mean={:.0}us",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.e2e_latency.mean_us(),
            self.e2e_latency.percentile_us(50.0),
            self.e2e_latency.percentile_us(99.0),
            self.execute_latency.mean_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(1000));
        assert_eq!(h.count(), 3);
        assert!((h.mean_us() - 370.0).abs() < 1.0);
        assert_eq!(h.max_us(), 1000);
        assert!(h.percentile_us(100.0) >= 1000);
        assert!(h.percentile_us(1.0) <= 16);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.percentile_us(50.0), 0);
    }

    #[test]
    fn zero_duration_clamps() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1));
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_us(), 1);
    }

    #[test]
    fn metrics_batch_mean() {
        let m = ServerMetrics::new();
        m.batches.store(2, Ordering::Relaxed);
        m.batched_items.store(9, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 4.5).abs() < 1e-9);
        assert!(m.summary().contains("mean_batch=4.50"));
    }
}
