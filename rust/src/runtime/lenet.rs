//! LeNet-5 executor: one compiled artifact + the (possibly modified)
//! weight literals, ready to classify batches.
//!
//! The HLO artifact takes weights as *arguments* (see
//! `python/compile/aot.py`), so a single compilation serves every
//! rounding variant: installing a variant only swaps the cached weight
//! literals — no recompile on the serving path.

use super::{tensor_to_literal, Executable, Runtime};
use crate::accel::LayerPairing;
// Wire order and conv-key knowledge live in one shared registry
// (`nn::params`) consumed by this executor, the paired CPU path, and the
// model builders alike.
use crate::nn::params::{CONV_KEYS, PARAM_NAMES};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Which artifact family to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Pallas-kernel forward (`lenet5_b{B}.hlo.txt`) — the paper-integrated path.
    Pallas,
    /// lax.conv forward (`lenet5_xla_b{B}.hlo.txt`) — XLA-native §Perf baseline.
    XlaNative,
}

impl Variant {
    pub fn artifact(&self, batch: usize) -> String {
        match self {
            Variant::Pallas => format!("lenet5_b{batch}.hlo.txt"),
            Variant::XlaNative => format!("lenet5_xla_b{batch}.hlo.txt"),
        }
    }
}

/// A compiled LeNet-5 with installed weights.
pub struct LeNet5Executor {
    exe: Executable,
    batch: usize,
    /// Cached weight literals in wire order.
    weight_literals: Vec<xla::Literal>,
    /// The dense weights currently installed (for introspection/tests).
    weights: HashMap<String, Tensor>,
    /// Rounding used to derive the installed weights (0 = original).
    rounding: f32,
}

impl LeNet5Executor {
    /// Load `artifacts/<variant>_b<batch>.hlo.txt` and install weights.
    pub fn load(
        rt: &Runtime,
        artifacts_dir: impl AsRef<Path>,
        variant: Variant,
        batch: usize,
        weights: &HashMap<String, Tensor>,
    ) -> Result<Self> {
        let path = artifacts_dir.as_ref().join(variant.artifact(batch));
        let exe = rt.load_hlo(&path)?;
        let mut s = Self {
            exe,
            batch,
            weight_literals: Vec::new(),
            weights: HashMap::new(),
            rounding: 0.0,
        };
        s.install_weights(weights, 0.0)?;
        Ok(s)
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn weights(&self) -> &HashMap<String, Tensor> {
        &self.weights
    }

    /// Install a weight set as the executor's cached literals.
    pub fn install_weights(
        &mut self,
        weights: &HashMap<String, Tensor>,
        rounding: f32,
    ) -> Result<()> {
        let mut lits = Vec::with_capacity(PARAM_NAMES.len());
        for name in PARAM_NAMES {
            let t = weights
                .get(name)
                .with_context(|| format!("weights missing {name}"))?;
            lits.push(tensor_to_literal(t)?);
        }
        self.weight_literals = lits;
        self.weights = weights.clone();
        self.rounding = rounding;
        Ok(())
    }

    /// Apply the paper's preprocessor at `rounding` to the conv layers of
    /// `base` weights and install the modified set. Returns total pairs.
    pub fn install_variant(
        &mut self,
        base: &HashMap<String, Tensor>,
        rounding: f32,
    ) -> Result<usize> {
        let mut modified = base.clone();
        let mut pairs = 0;
        for (key, _) in CONV_KEYS {
            let w = base.get(key).with_context(|| format!("missing {key}"))?;
            let pairing = LayerPairing::from_weights(w, rounding);
            pairs += pairing.total_pairs();
            modified.insert(key.to_string(), pairing.modified_weights(w));
        }
        self.install_weights(&modified, rounding)?;
        Ok(pairs)
    }

    /// Classify a `(B, 1, 32, 32)` batch → `(B, 10)` logits.
    pub fn execute(&self, batch: &Tensor) -> Result<Tensor> {
        if batch.shape() != [self.batch, 1, 32, 32] {
            bail!(
                "executor compiled for batch {}, got input {:?}",
                self.batch,
                batch.shape()
            );
        }
        let image = tensor_to_literal(batch)?;
        // weight literals are cached; only the image is materialized per call
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(1 + self.weight_literals.len());
        refs.push(&image);
        refs.extend(self.weight_literals.iter());
        self.exe.run(&refs)
    }
}
