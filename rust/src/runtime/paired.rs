//! Executor for the fully-paired LeNet-5 artifact — the configuration
//! where the paper's subtractor datapath *is* the served model: every
//! conv layer of `lenet5_paired_b{B}.hlo.txt` takes runtime pairing
//! tables (from Algorithm 1, run here in rust) instead of dense weights.

use super::{tensor_to_literal, Executable, Runtime};
use crate::accel::LayerPairing;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Fixed padded table sizes per conv layer: (weight key, Pmax, Umax).
/// Must match `python/compile/model.py::PAIRED_TABLE_SIZES`.
pub const PAIRED_TABLE_SIZES: [(&str, usize, usize); 3] =
    [("c1", 12, 25), ("c3", 75, 150), ("c5", 200, 400)];

/// A compiled fully-paired LeNet-5 with installed pairing tables.
pub struct PairedLeNet5Executor {
    exe: Executable,
    batch: usize,
    /// Cached argument literals after the image: 3 layers × 6 tables + head.
    table_literals: Vec<xla::Literal>,
    /// Pairs found per layer at the installed rounding.
    pairs_per_layer: Vec<usize>,
    rounding: f32,
}

impl PairedLeNet5Executor {
    /// Load `artifacts/lenet5_paired_b<batch>.hlo.txt` and install the
    /// pairing derived from `weights` at `rounding`.
    pub fn load(
        rt: &Runtime,
        artifacts_dir: impl AsRef<Path>,
        batch: usize,
        weights: &HashMap<String, Tensor>,
        rounding: f32,
    ) -> Result<Self> {
        let path = artifacts_dir.as_ref().join(format!("lenet5_paired_b{batch}.hlo.txt"));
        let exe = rt.load_hlo(&path)?;
        let mut s = Self {
            exe,
            batch,
            table_literals: Vec::new(),
            pairs_per_layer: Vec::new(),
            rounding,
        };
        s.install(weights, rounding)?;
        Ok(s)
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn pairs_per_layer(&self) -> &[usize] {
        &self.pairs_per_layer
    }

    /// Run Algorithm 1 per conv layer and cache the padded table literals.
    pub fn install(&mut self, weights: &HashMap<String, Tensor>, rounding: f32) -> Result<()> {
        let mut lits = Vec::new();
        let mut pairs_per_layer = Vec::new();
        for (name, pmax, umax) in PAIRED_TABLE_SIZES {
            let w = weights
                .get(&format!("{name}_w"))
                .with_context(|| format!("missing {name}_w"))?;
            let b = weights
                .get(&format!("{name}_b"))
                .with_context(|| format!("missing {name}_b"))?;
            let pairing = LayerPairing::from_weights(w, rounding);
            pairs_per_layer.push(pairing.total_pairs());
            let cout = w.shape()[0];
            let mut i1 = vec![0i32; cout * pmax];
            let mut i2 = vec![0i32; cout * pmax];
            let mut pk = vec![0f32; cout * pmax];
            let mut iu = vec![0i32; cout * umax];
            let mut wu = vec![0f32; cout * umax];
            for (c, f) in pairing.filters.iter().enumerate() {
                if f.n_pairs() > pmax || f.n_unpaired() > umax {
                    bail!("{name}: pairing exceeds artifact table sizes");
                }
                for j in 0..f.n_pairs() {
                    i1[c * pmax + j] = f.pair_i1[j] as i32;
                    i2[c * pmax + j] = f.pair_i2[j] as i32;
                    pk[c * pmax + j] = f.pair_k[j];
                }
                for j in 0..f.n_unpaired() {
                    iu[c * umax + j] = f.unp_idx[j] as i32;
                    wu[c * umax + j] = f.unp_w[j];
                }
            }
            let dims_p = [cout as i64, pmax as i64];
            let dims_u = [cout as i64, umax as i64];
            lits.push(xla::Literal::vec1(&i1).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&i2).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&pk).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&iu).reshape(&dims_u)?);
            lits.push(xla::Literal::vec1(&wu).reshape(&dims_u)?);
            lits.push(tensor_to_literal(b)?);
        }
        for key in ["f6_w", "f6_b", "out_w", "out_b"] {
            let t = weights.get(key).with_context(|| format!("missing {key}"))?;
            lits.push(tensor_to_literal(t)?);
        }
        self.table_literals = lits;
        self.rounding = rounding;
        self.pairs_per_layer = pairs_per_layer;
        Ok(())
    }

    /// Classify a `(B, 1, 32, 32)` batch → `(B, 10)` logits, entirely on
    /// the paired subtractor datapath.
    pub fn execute(&self, batch: &Tensor) -> Result<Tensor> {
        if batch.shape() != [self.batch, 1, 32, 32] {
            bail!("compiled for batch {}, got {:?}", self.batch, batch.shape());
        }
        let image = tensor_to_literal(batch)?;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(1 + self.table_literals.len());
        refs.push(&image);
        refs.extend(self.table_literals.iter());
        self.exe.run(&refs)
    }
}
