//! Executors for the fully-paired LeNet-5 — the configuration where the
//! paper's subtractor datapath *is* the served model.
//!
//! Two backends:
//!
//! * [`PairedLeNet5Executor`] — the PJRT artifact
//!   (`lenet5_paired_b{B}.hlo.txt`): every conv layer takes runtime
//!   pairing tables (from Algorithm 1, run here in rust) instead of
//!   dense weights.
//! * [`PairedCpuLeNet5`] — the same network on the in-process
//!   [`ConvEngine`] (no artifact, no PJRT): the whole network is
//!   compiled once into a [`CompiledNet`] (Algorithm 1 per conv layer)
//!   and served through per-batch-size [`crate::exec::ExecutionPlan`]
//!   executors, so the steady-state loop is allocation-free.

use super::{tensor_to_literal, Executable, Runtime};
use crate::accel::{AutotuneBudget, ConvEngine, LayerPairing, PackedPairing, TileCache, TileDecision};
use crate::exec::{CompiledNet, PlanExecutor};
use crate::nn::lenet5_try_from_params;
use crate::nn::params::{bias_key, weight_key};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// Fixed padded table sizes per conv layer: (weight key, Pmax, Umax).
/// Must match `python/compile/model.py::PAIRED_TABLE_SIZES`.
pub const PAIRED_TABLE_SIZES: [(&str, usize, usize); 3] =
    [("c1", 12, 25), ("c3", 75, 150), ("c5", 200, 400)];

/// A compiled fully-paired LeNet-5 with installed pairing tables.
pub struct PairedLeNet5Executor {
    exe: Executable,
    batch: usize,
    /// Cached argument literals after the image: 3 layers × 6 tables + head.
    table_literals: Vec<xla::Literal>,
    /// Pairs found per layer at the installed rounding.
    pairs_per_layer: Vec<usize>,
    rounding: f32,
}

impl PairedLeNet5Executor {
    /// Load `artifacts/lenet5_paired_b<batch>.hlo.txt` and install the
    /// pairing derived from `weights` at `rounding`.
    pub fn load(
        rt: &Runtime,
        artifacts_dir: impl AsRef<Path>,
        batch: usize,
        weights: &HashMap<String, Tensor>,
        rounding: f32,
    ) -> Result<Self> {
        let path = artifacts_dir.as_ref().join(format!("lenet5_paired_b{batch}.hlo.txt"));
        let exe = rt.load_hlo(&path)?;
        let mut s = Self {
            exe,
            batch,
            table_literals: Vec::new(),
            pairs_per_layer: Vec::new(),
            rounding,
        };
        s.install(weights, rounding)?;
        Ok(s)
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn pairs_per_layer(&self) -> &[usize] {
        &self.pairs_per_layer
    }

    /// Run Algorithm 1 per conv layer and cache the padded table literals.
    ///
    /// The padding itself lives with the packed layout
    /// ([`PackedPairing::padded_tables`], shared with the engine and its
    /// tests) — this runtime only reshapes the shared tables into XLA
    /// literals in the artifact's argument order.
    pub fn install(&mut self, weights: &HashMap<String, Tensor>, rounding: f32) -> Result<()> {
        let mut lits = Vec::new();
        let mut pairs_per_layer = Vec::new();
        for (name, pmax, umax) in PAIRED_TABLE_SIZES {
            let wk = weight_key(name);
            let bk = bias_key(name);
            let w = weights.get(&wk).with_context(|| format!("missing {wk}"))?;
            let b = weights.get(&bk).with_context(|| format!("missing {bk}"))?;
            let packed = PackedPairing::from_layer(&LayerPairing::from_weights(w, rounding));
            pairs_per_layer.push(packed.total_pairs());
            let t = packed
                .padded_tables(pmax, umax)
                .with_context(|| format!("{name}: pairing exceeds artifact table sizes"))?;
            let cout = packed.cout();
            let dims_p = [cout as i64, pmax as i64];
            let dims_u = [cout as i64, umax as i64];
            lits.push(xla::Literal::vec1(&t.pair_i1).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&t.pair_i2).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&t.pair_k).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&t.unp_idx).reshape(&dims_u)?);
            lits.push(xla::Literal::vec1(&t.unp_w).reshape(&dims_u)?);
            lits.push(tensor_to_literal(b)?);
        }
        for key in ["f6_w", "f6_b", "out_w", "out_b"] {
            let t = weights.get(key).with_context(|| format!("missing {key}"))?;
            lits.push(tensor_to_literal(t)?);
        }
        self.table_literals = lits;
        self.rounding = rounding;
        self.pairs_per_layer = pairs_per_layer;
        Ok(())
    }

    /// Classify a `(B, 1, 32, 32)` batch → `(B, 10)` logits, entirely on
    /// the paired subtractor datapath.
    pub fn execute(&self, batch: &Tensor) -> Result<Tensor> {
        if batch.shape() != [self.batch, 1, 32, 32] {
            bail!("compiled for batch {}, got {:?}", self.batch, batch.shape());
        }
        let image = tensor_to_literal(batch)?;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(1 + self.table_literals.len());
        refs.push(&image);
        refs.extend(self.table_literals.iter());
        self.exe.run(&refs)
    }
}

/// Pure-CPU paired LeNet-5 on a shared [`ConvEngine`] — the artifact-free
/// serving backend. The whole network (convs paired by Algorithm 1,
/// pooling, tanh, dense head) is compiled into one [`CompiledNet`] and
/// executed through a per-batch-size [`PlanExecutor`] cache, so repeat
/// batches of the same size run with zero steady-state allocations.
/// Batch-size flexible (no compiled shape): the first batch of a new size
/// resolves and warms a plan, later ones reuse it.
pub struct PairedCpuLeNet5 {
    engine: Arc<ConvEngine>,
    /// Shape-independent compile of the paired network at the installed
    /// rounding (stage 1 of the plan/execute split).
    net: CompiledNet,
    /// Warmed executors keyed by batch size (stage 2+3, one per shape).
    execs: HashMap<usize, PlanExecutor>,
    pairs_per_layer: Vec<usize>,
    rounding: f32,
}

impl PairedCpuLeNet5 {
    /// Build from trained weights (`weights.bin` keys, as in
    /// `python/compile/model.py`), pairing the conv layers at `rounding`.
    pub fn new(
        engine: Arc<ConvEngine>,
        weights: &HashMap<String, Tensor>,
        rounding: f32,
    ) -> Result<Self> {
        let net = compile_net(weights, rounding)?;
        let pairs_per_layer = net.pairs_per_conv().into_iter().map(|(_, p)| p).collect();
        Ok(Self { engine, net, execs: HashMap::new(), pairs_per_layer, rounding })
    }

    /// Re-run Algorithm 1 at a new rounding and swap in the recompiled
    /// network (dropping the now-stale executor cache). Returns total
    /// combined pairs (the variant-switch contract shared with
    /// [`super::LeNet5Executor::install_variant`]).
    pub fn install(&mut self, weights: &HashMap<String, Tensor>, rounding: f32) -> Result<usize> {
        self.net = compile_net(weights, rounding)?;
        self.pairs_per_layer = self.net.pairs_per_conv().into_iter().map(|(_, p)| p).collect();
        self.rounding = rounding;
        self.execs.clear();
        Ok(self.total_pairs())
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn pairs_per_layer(&self) -> &[usize] {
        &self.pairs_per_layer
    }

    pub fn total_pairs(&self) -> usize {
        self.pairs_per_layer.iter().sum()
    }

    /// The engine this executor runs on.
    pub fn engine(&self) -> &Arc<ConvEngine> {
        &self.engine
    }

    /// Resolve + warm the plan for `batch` ahead of traffic, so the first
    /// real request at that size already runs allocation-free.
    pub fn warm(&mut self, batch: usize) -> Result<()> {
        self.executor_for(batch)?;
        Ok(())
    }

    /// [`PairedCpuLeNet5::warm`] plus the one-shot row-tile autotune
    /// sweep per conv layer ([`crate::accel::autotune`]): all sweep cost
    /// lands here, before traffic, and the decisions stick for the plan's
    /// lifetime. Returns the per-layer decisions (for logging or
    /// trajectory persistence). Idempotent per batch size.
    pub fn warm_autotuned(
        &mut self,
        batch: usize,
        budget: &AutotuneBudget,
        cache: Option<&TileCache>,
    ) -> Result<Vec<TileDecision>> {
        let engine = Arc::clone(&self.engine);
        let exe = self.executor_for(batch)?;
        Ok(exe.warm_autotuned(&engine, budget, cache).to_vec())
    }

    fn executor_for(&mut self, batch: usize) -> Result<&mut PlanExecutor> {
        if !self.execs.contains_key(&batch) {
            let mut exe = self.net.plan(&[batch, 1, 32, 32])?.into_executor();
            exe.warm();
            self.execs.insert(batch, exe);
        }
        Ok(self.execs.get_mut(&batch).expect("just inserted"))
    }

    /// Classify a `(B, 1, 32, 32)` batch → `(B, 10)` logits on the paired
    /// CPU datapath (any batch size).
    pub fn execute(&mut self, batch: &Tensor) -> Result<Tensor> {
        let s = batch.shape();
        if s.len() != 4 || s[1] != 1 || s[2] != 32 || s[3] != 32 {
            bail!("expected (B,1,32,32) input, got {s:?}");
        }
        let engine = Arc::clone(&self.engine);
        let exe = self.executor_for(s[0])?;
        Ok(exe.infer(&engine, batch)?)
    }
}

/// Stage-1 compile: build the LeNet-5 topology from the wire params and
/// pair its conv layers at `rounding`.
fn compile_net(weights: &HashMap<String, Tensor>, rounding: f32) -> Result<CompiledNet> {
    let model = lenet5_try_from_params(weights).context("building LeNet-5 from weights")?;
    Ok(CompiledNet::compile(&model, rounding))
}
