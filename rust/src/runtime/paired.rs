//! Executors for the fully-paired LeNet-5 — the configuration where the
//! paper's subtractor datapath *is* the served model.
//!
//! Two backends:
//!
//! * [`PairedLeNet5Executor`] — the PJRT artifact
//!   (`lenet5_paired_b{B}.hlo.txt`): every conv layer takes runtime
//!   pairing tables (from Algorithm 1, run here in rust) instead of
//!   dense weights.
//! * [`PairedCpuLeNet5`] — the same network on the in-process
//!   [`ConvEngine`] (no artifact, no PJRT): conv layers run the packed
//!   pairing through a shared multi-threaded engine, pooling/dense run
//!   the ordinary [`crate::nn::layers`] code.

use super::{tensor_to_literal, Executable, Runtime};
use crate::accel::{ConvEngine, LayerPairing, SubConv2d};
use crate::nn::layers::{avgpool2, dense_layer, tanh_inplace};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// Fixed padded table sizes per conv layer: (weight key, Pmax, Umax).
/// Must match `python/compile/model.py::PAIRED_TABLE_SIZES`.
pub const PAIRED_TABLE_SIZES: [(&str, usize, usize); 3] =
    [("c1", 12, 25), ("c3", 75, 150), ("c5", 200, 400)];

/// A compiled fully-paired LeNet-5 with installed pairing tables.
pub struct PairedLeNet5Executor {
    exe: Executable,
    batch: usize,
    /// Cached argument literals after the image: 3 layers × 6 tables + head.
    table_literals: Vec<xla::Literal>,
    /// Pairs found per layer at the installed rounding.
    pairs_per_layer: Vec<usize>,
    rounding: f32,
}

impl PairedLeNet5Executor {
    /// Load `artifacts/lenet5_paired_b<batch>.hlo.txt` and install the
    /// pairing derived from `weights` at `rounding`.
    pub fn load(
        rt: &Runtime,
        artifacts_dir: impl AsRef<Path>,
        batch: usize,
        weights: &HashMap<String, Tensor>,
        rounding: f32,
    ) -> Result<Self> {
        let path = artifacts_dir.as_ref().join(format!("lenet5_paired_b{batch}.hlo.txt"));
        let exe = rt.load_hlo(&path)?;
        let mut s = Self {
            exe,
            batch,
            table_literals: Vec::new(),
            pairs_per_layer: Vec::new(),
            rounding,
        };
        s.install(weights, rounding)?;
        Ok(s)
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn pairs_per_layer(&self) -> &[usize] {
        &self.pairs_per_layer
    }

    /// Run Algorithm 1 per conv layer and cache the padded table literals.
    pub fn install(&mut self, weights: &HashMap<String, Tensor>, rounding: f32) -> Result<()> {
        let mut lits = Vec::new();
        let mut pairs_per_layer = Vec::new();
        for (name, pmax, umax) in PAIRED_TABLE_SIZES {
            let w = weights
                .get(&format!("{name}_w"))
                .with_context(|| format!("missing {name}_w"))?;
            let b = weights
                .get(&format!("{name}_b"))
                .with_context(|| format!("missing {name}_b"))?;
            let pairing = LayerPairing::from_weights(w, rounding);
            pairs_per_layer.push(pairing.total_pairs());
            let cout = w.shape()[0];
            let mut i1 = vec![0i32; cout * pmax];
            let mut i2 = vec![0i32; cout * pmax];
            let mut pk = vec![0f32; cout * pmax];
            let mut iu = vec![0i32; cout * umax];
            let mut wu = vec![0f32; cout * umax];
            for (c, f) in pairing.filters.iter().enumerate() {
                if f.n_pairs() > pmax || f.n_unpaired() > umax {
                    bail!("{name}: pairing exceeds artifact table sizes");
                }
                for j in 0..f.n_pairs() {
                    i1[c * pmax + j] = f.pair_i1[j] as i32;
                    i2[c * pmax + j] = f.pair_i2[j] as i32;
                    pk[c * pmax + j] = f.pair_k[j];
                }
                for j in 0..f.n_unpaired() {
                    iu[c * umax + j] = f.unp_idx[j] as i32;
                    wu[c * umax + j] = f.unp_w[j];
                }
            }
            let dims_p = [cout as i64, pmax as i64];
            let dims_u = [cout as i64, umax as i64];
            lits.push(xla::Literal::vec1(&i1).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&i2).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&pk).reshape(&dims_p)?);
            lits.push(xla::Literal::vec1(&iu).reshape(&dims_u)?);
            lits.push(xla::Literal::vec1(&wu).reshape(&dims_u)?);
            lits.push(tensor_to_literal(b)?);
        }
        for key in ["f6_w", "f6_b", "out_w", "out_b"] {
            let t = weights.get(key).with_context(|| format!("missing {key}"))?;
            lits.push(tensor_to_literal(t)?);
        }
        self.table_literals = lits;
        self.rounding = rounding;
        self.pairs_per_layer = pairs_per_layer;
        Ok(())
    }

    /// Classify a `(B, 1, 32, 32)` batch → `(B, 10)` logits, entirely on
    /// the paired subtractor datapath.
    pub fn execute(&self, batch: &Tensor) -> Result<Tensor> {
        if batch.shape() != [self.batch, 1, 32, 32] {
            bail!("compiled for batch {}, got {:?}", self.batch, batch.shape());
        }
        let image = tensor_to_literal(batch)?;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(1 + self.table_literals.len());
        refs.push(&image);
        refs.extend(self.table_literals.iter());
        self.exe.run(&refs)
    }
}

/// Pure-CPU paired LeNet-5 on a shared [`ConvEngine`] — the artifact-free
/// serving backend. Conv layers (c1/c3/c5) execute their packed pairing
/// on the engine's worker pool; pooling, tanh, and the dense head reuse
/// the [`crate::nn::layers`] kernels. Batch-size flexible (no compiled
/// shape), so the coordinator can serve any padded batch with it.
pub struct PairedCpuLeNet5 {
    engine: Arc<ConvEngine>,
    /// c1, c3, c5 compiled at the installed rounding.
    units: Vec<SubConv2d>,
    f6_w: Tensor,
    f6_b: Tensor,
    out_w: Tensor,
    out_b: Tensor,
    pairs_per_layer: Vec<usize>,
    rounding: f32,
}

const CPU_CONV_KEYS: [&str; 3] = ["c1", "c3", "c5"];

impl PairedCpuLeNet5 {
    /// Build from trained weights (`weights.bin` keys, as in
    /// `python/compile/model.py`), pairing the conv layers at `rounding`.
    pub fn new(
        engine: Arc<ConvEngine>,
        weights: &HashMap<String, Tensor>,
        rounding: f32,
    ) -> Result<Self> {
        let get = |k: &str| {
            weights.get(k).cloned().with_context(|| format!("missing {k}"))
        };
        let mut s = Self {
            engine,
            units: Vec::new(),
            f6_w: get("f6_w")?,
            f6_b: get("f6_b")?,
            out_w: get("out_w")?,
            out_b: get("out_b")?,
            pairs_per_layer: Vec::new(),
            rounding,
        };
        s.install(weights, rounding)?;
        Ok(s)
    }

    /// Re-run Algorithm 1 at a new rounding and swap in the recompiled
    /// units. Returns total combined pairs (the variant-switch contract
    /// shared with [`super::LeNet5Executor::install_variant`]).
    pub fn install(&mut self, weights: &HashMap<String, Tensor>, rounding: f32) -> Result<usize> {
        let mut units = Vec::with_capacity(CPU_CONV_KEYS.len());
        let mut pairs_per_layer = Vec::with_capacity(CPU_CONV_KEYS.len());
        for name in CPU_CONV_KEYS {
            let w = weights
                .get(&format!("{name}_w"))
                .with_context(|| format!("missing {name}_w"))?;
            let b = weights
                .get(&format!("{name}_b"))
                .with_context(|| format!("missing {name}_b"))?;
            let unit = SubConv2d::compile(w, b, rounding);
            pairs_per_layer.push(unit.total_pairs());
            units.push(unit);
        }
        self.units = units;
        self.pairs_per_layer = pairs_per_layer;
        self.rounding = rounding;
        Ok(self.total_pairs())
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn pairs_per_layer(&self) -> &[usize] {
        &self.pairs_per_layer
    }

    pub fn total_pairs(&self) -> usize {
        self.pairs_per_layer.iter().sum()
    }

    /// The engine this executor runs on.
    pub fn engine(&self) -> &Arc<ConvEngine> {
        &self.engine
    }

    /// Classify a `(B, 1, 32, 32)` batch → `(B, 10)` logits on the paired
    /// CPU datapath (any batch size).
    pub fn execute(&self, batch: &Tensor) -> Result<Tensor> {
        let s = batch.shape();
        if s.len() != 4 || s[1] != 1 || s[2] != 32 || s[3] != 32 {
            bail!("expected (B,1,32,32) input, got {s:?}");
        }
        let b = s[0];
        // c1 → tanh → s2, c3 → tanh → s4 (LeNet-5, paper Fig 2)
        let (mut h, _) = self.units[0].forward_with(&self.engine, batch)?;
        tanh_inplace(&mut h);
        let mut h = avgpool2(&h);
        let (mut h3, _) = self.units[1].forward_with(&self.engine, &h)?;
        tanh_inplace(&mut h3);
        h = avgpool2(&h3);
        // c5 → tanh → flatten (B, 120)
        let (mut h5, _) = self.units[2].forward_with(&self.engine, &h)?;
        tanh_inplace(&mut h5);
        let flat = h5.reshape(&[b, 120]);
        // dense head
        let mut f6 = dense_layer(&flat, &self.f6_w, &self.f6_b);
        tanh_inplace(&mut f6);
        Ok(dense_layer(&f6, &self.out_w, &self.out_b))
    }
}
