//! PJRT runtime: loads the AOT-lowered HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). All artifacts
//! are lowered with `return_tuple=True`, so results unwrap with
//! `to_tuple1`.
//!
//! The PJRT client is not `Sync`; the coordinator therefore owns the
//! executor on a dedicated worker thread (actor pattern) — see
//! [`crate::coordinator`].

mod lenet;
mod paired;

pub use lenet::{LeNet5Executor, Variant};
pub use paired::{PairedCpuLeNet5, PairedLeNet5Executor, PAIRED_TABLE_SIZES};

use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client plus helpers to load artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Construct a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with literal inputs (owned or borrowed); unwraps the
    /// 1-tuple output and returns the result as a [`Tensor`] (f32).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(&self, inputs: &[L]) -> Result<Tensor> {
        let result = self
            .exe
            .execute(inputs)
            .with_context(|| format!("execute {}", self.name))?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1().context("unwrap 1-tuple output")?;
        literal_to_tensor(&out)
    }
}

/// Convert a [`Tensor`] into an `xla::Literal` of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// Convert an f32 `xla::Literal` back into a [`Tensor`].
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal has no array shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("literal to f32 vec")?;
    Ok(Tensor::new(&dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/ (integration);
    // here we only cover the pure conversion helpers.
    #[test]
    fn tensor_literal_roundtrip() -> Result<()> {
        let t = Tensor::new(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.0]);
        let l = tensor_to_literal(&t)?;
        let back = literal_to_tensor(&l)?;
        assert_eq!(back, t);
        Ok(())
    }

    #[test]
    fn scalarish_roundtrip() -> Result<()> {
        let t = Tensor::new(&[1], vec![42.0]);
        let back = literal_to_tensor(&tensor_to_literal(&t)?)?;
        assert_eq!(back.data(), &[42.0]);
        Ok(())
    }
}
