//! `subaccel` — CLI for the Subtractor-Based CNN Inference Accelerator.
//!
//! Subcommands map onto the paper's experiments (DESIGN.md §5):
//!
//! * `sweep --table1` — regenerate Table 1 / Fig 7 (op counts per rounding)
//! * `sweep --fig8`   — regenerate Fig 8 (accuracy vs power/area savings)
//! * `report`         — Fig 3 / Fig 4 weight distributions + pair stats
//! * `profile`        — Fig 1 AlexNet per-layer time share
//! * `infer`          — classify test images through any engine
//! * `serve`          — run the serving coordinator demo
//!
//! Argument parsing is hand-rolled (`Args`): the offline vendor set has
//! no clap.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use subaccel::accel::{
    histogram, model_op_sweep, ConvEngine, LayerPairing, WeightStats, TABLE1_ROUNDINGS,
};
use subaccel::coordinator::{Backend, Coordinator, ServeConfig};
use subaccel::data::{load_dataset, load_weights, Dataset};
use subaccel::hw::{savings_report, CostModel};
use subaccel::nn::{alexnet, lenet5_from_params, Model};
use subaccel::runtime::{PairedCpuLeNet5, Variant};
use subaccel::tensor::Tensor;

const USAGE: &str = "\
subaccel — subtractor-based CNN inference accelerator (Gao et al., 2023)

USAGE: subaccel [--artifacts DIR] <command> [options]

COMMANDS
  sweep    [--table1] [--fig8] [--limit N]     Table 1 / Fig 7 / Fig 8
  report   [--layer c1|c3|c5] [--bins N]       Fig 3 / Fig 4 weight report
  profile  [--reps N]                          Fig 1 AlexNet layer profile
  infer    [--count N] [--engine rust|subconv|pallas|xla|paired] [--rounding R]
           [--threads N]
           (subconv = the in-process paired engine on N threads, 0 = all
            cores; paired = the fully-paired AOT artifact: every conv
            layer runs the subtractor datapath inside the PJRT executable)
  serve    [--requests N] [--batch N] [--rounding R] [--clients N]
           [--engine pallas|xla|cpu] [--workers N] [--threads N]
           (pallas/xla need compiled artifacts, batch 1/8/32; cpu runs the
            paired engine in-process with N threads per worker, any batch)
  synth    [--rounding R] [--mac-lanes N] [--sub-lanes N]
           virtual synthesis: absolute power/area/cycles per design point
";

/// Tiny flag parser: `--key value` pairs after a positional command.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut cmd = String::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags: next token missing or is another flag
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                if !cmd.is_empty() {
                    bail!("unexpected positional argument {a}");
                }
                cmd = a.clone();
                i += 1;
            }
        }
        Ok(Self { cmd, flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid value for --{key}: {v}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv)?;
    let artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    match args.cmd.as_str() {
        "sweep" => sweep(&artifacts, &args),
        "report" => report(&artifacts, &args),
        "profile" => profile(&args),
        "infer" => infer(&artifacts, &args),
        "serve" => serve(&artifacts, &args),
        "synth" => synth(&artifacts, &args),
        other => {
            print!("{USAGE}");
            bail!("unknown command {other:?}")
        }
    }
}

fn load_model(artifacts: &PathBuf) -> Result<Model> {
    let weights = load_weights(artifacts.join("weights.bin"))
        .context("load trained weights (run `make artifacts`)")?;
    Ok(lenet5_from_params(&weights))
}

fn sweep(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let table1 = args.has("table1");
    let fig8 = args.has("fig8");
    let limit: usize = args.get("limit", 500)?;
    let model = load_model(artifacts)?;
    let rows = model_op_sweep(&model, &[1, 1, 32, 32], &TABLE1_ROUNDINGS);

    if table1 || !fig8 {
        println!("# Table 1 — operations per inference (LeNet-5 conv layers)");
        println!(
            "{:>9} {:>10} {:>13} {:>16} {:>9}",
            "rounding", "additions", "subtractions", "multiplications", "total"
        );
        for r in &rows {
            println!(
                "{:>9} {:>10} {:>13} {:>16} {:>9}",
                r.rounding, r.adds, r.subs, r.muls, r.total
            );
        }
    }

    if fig8 {
        let ds = load_dataset(artifacts.join("dataset.bin"))?;
        let n = limit.min(ds.n);
        let cost = CostModel::ieee754_f32();
        let baseline = &rows[0];
        println!(
            "\n# Fig 8 — accuracy vs power/area savings ({n} images, cost model {})",
            cost.name
        );
        println!(
            "{:>9} {:>10} {:>10} {:>9} {:>10} {:>10}",
            "rounding", "power_sav%", "area_sav%", "ops_sav%", "accuracy%", "pairs"
        );
        for row in &rows {
            let s = savings_report(&cost, baseline, row);
            let acc = eval_accuracy(&model, &ds, n, row.rounding);
            let pairs: u64 = row.layers.iter().map(|(_, p, _)| p).sum();
            println!(
                "{:>9} {:>10.2} {:>10.2} {:>9.2} {:>10.2} {:>10}",
                row.rounding,
                s.power_saving_pct,
                s.area_saving_pct,
                s.ops_saving_pct,
                acc * 100.0,
                pairs
            );
        }
    }
    Ok(())
}

/// Accuracy of the rounding variant on the first `n` test images (dense
/// engine with modified weights — numerically identical to the paired
/// datapath, see accel::subconv tests).
fn eval_accuracy(model: &Model, ds: &Dataset, n: usize, rounding: f32) -> f64 {
    let mut m = model.clone();
    if rounding > 0.0 {
        for info in model.conv_layers(&[1, 1, 32, 32]) {
            let pairing = LayerPairing::from_weights(&info.weight, rounding);
            m.set_conv_weights(&info.name, pairing.modified_weights(&info.weight));
        }
    }
    let mut hits = 0usize;
    for i in 0..n {
        let logits = m.infer(&ds.image32(i));
        if logits.argmax_rows()[0] == ds.labels[i] as usize {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

fn report(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let layer = args.str("layer", "c3");
    let bins: usize = args.get("bins", 41)?;
    let model = load_model(artifacts)?;
    let infos = model.conv_layers(&[1, 1, 32, 32]);
    let info = infos
        .iter()
        .find(|i| i.name == layer)
        .with_context(|| format!("unknown conv layer {layer} (have: c1, c3, c5)"))?;
    let w = info.weight.data();
    let stats = WeightStats::compute(w);
    println!("# Fig 3/4 — weight distribution, layer {layer} ({:?})", info.weight.shape());
    println!("{stats:#?}");
    let lim = stats.min.abs().max(stats.max.abs());
    println!("\nhistogram:");
    print!("{}", histogram(w, -lim, lim, bins).render(60));
    for r in [0.01f32, 0.05, 0.1] {
        let p = LayerPairing::from_weights(&info.weight, r);
        println!(
            "rounding {:>5}: {} pairs / {} weights ({:.1}% combined), max snap err {:.5}",
            r,
            p.total_pairs(),
            info.weight.len(),
            200.0 * p.total_pairs() as f32 / info.weight.len() as f32,
            p.max_snap_error(&info.weight)
        );
    }
    // CSV dumps for external plotting: Fig 3 = raw values, Fig 4 = histogram
    std::fs::create_dir_all(artifacts.join("results"))?;
    let mut fig3 = String::from("index,weight\n");
    for (i, v) in w.iter().enumerate() {
        fig3.push_str(&format!("{i},{v}\n"));
    }
    std::fs::write(artifacts.join("results").join(format!("fig3_{layer}_weights.csv")), fig3)?;
    let h = histogram(w, -lim, lim, bins);
    let mut fig4 = String::from("bin_lo,bin_hi,count\n");
    for (i, &c) in h.counts.iter().enumerate() {
        let lo = h.lo + h.bin_width() * i as f32;
        fig4.push_str(&format!("{lo},{},{c}\n", lo + h.bin_width()));
    }
    std::fs::write(artifacts.join("results").join(format!("fig4_{layer}_hist.csv")), fig4)?;
    println!("\nwrote artifacts/results/fig3_{layer}_weights.csv and fig4_{layer}_hist.csv");
    Ok(())
}

fn profile(args: &Args) -> Result<()> {
    let reps: usize = args.get("reps", 3)?;
    let m = alexnet();
    let x = Tensor::zeros(&[1, 3, 227, 227]);
    println!("# Fig 1 — AlexNet inference time share per layer ({reps} reps, pure-rust engine)");
    let mut acc: Vec<(String, f64, u64)> = Vec::new();
    for _ in 0..reps {
        for (i, (name, secs, counts)) in m.profile(&x).into_iter().enumerate() {
            if acc.len() <= i {
                acc.push((name, 0.0, counts.muls));
            }
            acc[i].1 += secs;
        }
    }
    let total: f64 = acc.iter().map(|(_, t, _)| t).sum();
    println!("{:>8} {:>10} {:>8} {:>14}", "layer", "time_ms", "time_%", "macs");
    for (name, t, macs) in &acc {
        println!(
            "{:>8} {:>10.2} {:>8.2} {:>14}",
            name,
            t * 1e3 / reps as f64,
            100.0 * t / total,
            macs
        );
    }
    let conv: f64 = acc
        .iter()
        .filter(|(n, ..)| n.starts_with("conv"))
        .map(|(_, t, _)| *t)
        .sum();
    println!("\nconv layers: {:.1}% of inference time (paper Fig 1: ~90%)", 100.0 * conv / total);
    Ok(())
}

fn infer(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let count: usize = args.get("count", 8)?;
    let engine = args.str("engine", "rust");
    let rounding: f32 = args.get("rounding", 0.0)?;
    let weights = load_weights(artifacts.join("weights.bin"))?;
    let ds = load_dataset(artifacts.join("dataset.bin"))?;
    let n = count.min(ds.n);
    let mut hits = 0usize;
    match engine.as_str() {
        "rust" => {
            let model = lenet5_from_params(&weights);
            let mut m = model.clone();
            if rounding > 0.0 {
                for info in model.conv_layers(&[1, 1, 32, 32]) {
                    let p = LayerPairing::from_weights(&info.weight, rounding);
                    m.set_conv_weights(&info.name, p.modified_weights(&info.weight));
                }
            }
            for i in 0..n {
                hits += (m.infer(&ds.image32(i)).argmax_rows()[0] == ds.labels[i] as usize) as usize;
            }
        }
        "subconv" => {
            // the actual paired subtractor datapath for conv layers, on
            // the in-process engine (--threads 0 = all cores)
            let threads = match args.get("threads", 1usize)? {
                0 => ConvEngine::host_threads(),
                t => t,
            };
            let engine = Arc::new(ConvEngine::new(threads)?);
            let mut exe = PairedCpuLeNet5::new(engine, &weights, rounding)?;
            println!("pairs per conv layer: {:?} ({threads} threads)", exe.pairs_per_layer());
            for i in 0..n {
                let logits = exe.execute(&ds.image32(i))?;
                hits += (logits.argmax_rows()[0] == ds.labels[i] as usize) as usize;
            }
        }
        "pallas" | "xla" => {
            let variant = if engine == "pallas" { Variant::Pallas } else { Variant::XlaNative };
            let rt = subaccel::runtime::Runtime::cpu()?;
            let mut exe =
                subaccel::runtime::LeNet5Executor::load(&rt, artifacts, variant, 1, &weights)?;
            if rounding > 0.0 {
                exe.install_variant(&weights, rounding)?;
            }
            for i in 0..n {
                let logits = exe.execute(&ds.image32(i))?;
                hits += (logits.argmax_rows()[0] == ds.labels[i] as usize) as usize;
            }
        }
        "paired" => {
            let rt = subaccel::runtime::Runtime::cpu()?;
            let exe = subaccel::runtime::PairedLeNet5Executor::load(
                &rt, artifacts, 1, &weights, rounding,
            )?;
            println!("pairs per conv layer: {:?}", exe.pairs_per_layer());
            for i in 0..n {
                let logits = exe.execute(&ds.image32(i))?;
                hits += (logits.argmax_rows()[0] == ds.labels[i] as usize) as usize;
            }
        }
        other => bail!("unknown engine {other} (rust|subconv|pallas|xla|paired)"),
    }
    println!("{hits}/{n} correct ({:.2}%) at rounding {rounding} [{engine}]", 100.0 * hits as f64 / n as f64);
    Ok(())
}

/// Virtual synthesis: absolute design-point numbers (the paper reports
/// percentages only; these make the cost model inspectable).
fn synth(artifacts: &PathBuf, args: &Args) -> Result<()> {
    use subaccel::hw::{synthesize, PeArrayConfig, PeArraySim};
    let rounding: f32 = args.get("rounding", 0.05)?;
    let mac_lanes: usize = args.get("mac-lanes", 16)?;
    let sub_lanes: usize = args.get("sub-lanes", 8)?;
    let model = load_model(artifacts)?;
    let cost = CostModel::ieee754_f32();
    println!("# virtual synthesis ({}, 1 inference, conv layers)", cost.name);
    println!(
        "{:>9} {:>12} {:>10} {:>10} {:>12}",
        "rounding", "energy_nJ", "power_mW", "area_mm2", "cycles(64sl)"
    );
    for r in [0.0f32, rounding] {
        let ops = subaccel::accel::model_ops(&model, &[1, 1, 32, 32], r);
        let s = synthesize(&cost, &ops);
        println!(
            "{:>9} {:>12.2} {:>10.2} {:>10.4} {:>12}",
            r, s.energy_nj, s.power_mw, s.area_mm2, s.cycles
        );
    }
    let sim = PeArraySim::new(PeArrayConfig {
        mac_lanes,
        sub_lanes,
        frequency_ghz: cost.frequency_ghz,
    });
    println!("\n# PE-array schedule ({mac_lanes} MAC + {sub_lanes} sub lanes)");
    println!("{:>9} {:>12} {:>12} {:>9} {:>9}", "rounding", "cycles", "latency_us", "mac_util", "sub_util");
    for r in [0.0f32, rounding] {
        let infos = model.conv_layers(&[1, 1, 32, 32]);
        let pairings: Vec<(LayerPairing, usize)> = infos
            .iter()
            .map(|i| (LayerPairing::from_weights(&i.weight, r), i.out_positions))
            .collect();
        let refs: Vec<(&LayerPairing, usize)> = pairings.iter().map(|(p, n)| (p, *n)).collect();
        let rep = sim.simulate_model(&refs);
        println!(
            "{:>9} {:>12} {:>12.1} {:>9.3} {:>9.3}",
            r, rep.cycles, rep.latency_us, rep.mac_utilization, rep.sub_utilization
        );
    }
    Ok(())
}

fn serve(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let requests: usize = args.get("requests", 256)?;
    let batch: usize = args.get("batch", 8)?;
    let rounding: f32 = args.get("rounding", 0.05)?;
    let clients: usize = args.get("clients", 8)?;
    let engine = args.str("engine", "xla");
    let backend = match engine.as_str() {
        "pallas" => Backend::Pjrt(Variant::Pallas),
        "xla" => Backend::Pjrt(Variant::XlaNative),
        "cpu" => Backend::CpuEngine,
        other => bail!("unknown engine {other} (pallas|xla|cpu)"),
    };
    let workers: usize = args.get("workers", 1)?;
    let threads = match args.get("threads", 1usize)? {
        0 => ConvEngine::host_threads(),
        t => t,
    };
    // the builder rejects invalid combinations (e.g. a PJRT batch size
    // with no compiled artifact) before any thread spawns
    let cfg = ServeConfig::builder()
        .artifacts_dir(artifacts.clone())
        .backend(backend)
        .batch_size(batch)
        .rounding(rounding)
        .workers(workers)
        .engine_threads(threads)
        .build()?;
    let coord = std::sync::Arc::new(Coordinator::start(cfg)?);
    let ds = std::sync::Arc::new(load_dataset(artifacts.join("dataset.bin"))?);
    let per_client = requests / clients.max(1);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        let ds = ds.clone();
        handles.push(std::thread::spawn(move || {
            let mut hits = 0usize;
            for i in 0..per_client {
                let idx = (c * per_client + i) % ds.n;
                loop {
                    match coord.classify(ds.image32(idx)) {
                        Ok(logits) => {
                            let pred = logits
                                .iter()
                                .enumerate()
                                .max_by(|a, b| a.1.total_cmp(b.1))
                                .map(|(j, _)| j)
                                .unwrap();
                            hits += (pred == ds.labels[idx] as usize) as usize;
                            break;
                        }
                        Err(_) => std::thread::sleep(std::time::Duration::from_micros(200)),
                    }
                }
            }
            hits
        }));
    }
    let hits: usize = handles.into_iter().map(|h| h.join().unwrap_or(0)).sum();
    let dt = t0.elapsed();
    let done = clients * per_client;
    println!(
        "served {done} requests in {:.2}s → {:.1} req/s [{engine}, batch {batch}]",
        dt.as_secs_f64(),
        done as f64 / dt.as_secs_f64()
    );
    println!("accuracy {:.2}% at rounding {rounding}", 100.0 * hits as f64 / done as f64);
    let snap = coord.metrics().snapshot();
    println!("{snap}");
    println!(
        "latency tail: e2e p99 {}us (max {}us), exec p99 {}us, queue p99 {}us",
        snap.e2e.p99_us, snap.e2e.max_us, snap.execute.p99_us, snap.queue.p99_us
    );
    Ok(())
}
