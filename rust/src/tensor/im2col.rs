//! im2col patch extraction — the layout contract shared with the python
//! kernels (`python/compile/kernels/ref.py::im2col`): the patch axis is
//! ordered `(c, dy, dx)`, exactly the order `w.reshape(Cout, -1)` produces
//! from OIHW weights. Both the dense engine and the subtractor unit index
//! patches with the same flat weight index, so the orders must agree.
//!
//! Two entry points:
//!
//! * [`im2col`] / [`im2col_geo`] — allocate a fresh patch matrix (the
//!   original API, kept for tests and one-shot callers).
//! * [`im2col_into`] / [`im2col_slice_into`] — write into a caller-owned
//!   buffer; the engine hot path ([`crate::accel::ConvEngine`]) reuses one
//!   buffer across calls so steady-state forwards do not allocate patches.
//!   The slice variant takes raw NCHW data, for callers whose activations
//!   live in scratch buffers rather than `Tensor`s ([`crate::exec`]).

use super::Tensor;

/// Result of patch extraction: a `(B*OH*OW, K)` matrix plus geometry.
pub struct Im2col {
    /// `(rows, k)` patch matrix, row-major.
    pub patches: Tensor,
    pub batch: usize,
    pub out_h: usize,
    pub out_w: usize,
    /// K = C·kh·kw.
    pub k: usize,
}

/// Geometry of a patch extraction (no data) — what [`im2col_into`]
/// returns alongside the filled buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Im2colShape {
    /// B·OH·OW.
    pub rows: usize,
    /// C·kh·kw.
    pub k: usize,
    pub batch: usize,
    pub out_h: usize,
    pub out_w: usize,
}

/// Output geometry for an NCHW input under the given kernel/stride and
/// (possibly asymmetric) zero padding: `pad_h` rows above and below,
/// `pad_w` columns left and right. Panics on impossible geometry (the
/// callers treat that as a programming error, matching the engine's
/// assert conventions).
pub fn im2col_shape(
    shape: &[usize],
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
) -> Im2colShape {
    assert_eq!(shape.len(), 4, "im2col expects NCHW, got {shape:?}");
    assert!(stride >= 1, "stride must be >= 1");
    let (b, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
    let (hp, wp) = (h + 2 * pad_h, w + 2 * pad_w);
    assert!(
        hp >= kh && wp >= kw,
        "kernel {kh}x{kw} larger than input {h}x{w} (pad {pad_h}x{pad_w})"
    );
    let oh = (hp - kh) / stride + 1;
    let ow = (wp - kw) / stride + 1;
    Im2colShape { rows: b * oh * ow, k: c * kh * kw, batch: b, out_h: oh, out_w: ow }
}

/// Extract valid-convolution patches from an NCHW tensor (stride 1).
///
/// `x`: `(B, C, H, W)` → rows ordered `(b, oy, ox)`, columns ordered
/// `(c, dy, dx)`.
pub fn im2col(x: &Tensor, kh: usize, kw: usize) -> Im2col {
    im2col_geo(x, kh, kw, 1, 0, 0)
}

/// [`im2col`] generalized to strided, zero-padded convolution with
/// independent row/column padding.
pub fn im2col_geo(
    x: &Tensor,
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
) -> Im2col {
    let mut buf = Vec::new();
    let s = im2col_into(x, kh, kw, stride, pad_h, pad_w, &mut buf);
    Im2col {
        patches: Tensor::new(&[s.rows, s.k], buf),
        batch: s.batch,
        out_h: s.out_h,
        out_w: s.out_w,
        k: s.k,
    }
}

/// Patch extraction into a caller-owned buffer. The buffer is resized to
/// `rows * k` and fully overwritten; reusing one buffer across calls of
/// the same geometry performs zero allocation after the first call.
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    x: &Tensor,
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    out: &mut Vec<f32>,
) -> Im2colShape {
    im2col_slice_into(x.data(), x.shape(), kh, kw, stride, pad_h, pad_w, out)
}

/// [`im2col_into`] on a raw NCHW slice. The whole-network executor in
/// [`crate::exec`] keeps activations in reusable scratch buffers rather
/// than `Tensor`s, so the engine needs an entry point that never touches
/// a tensor handle.
#[allow(clippy::too_many_arguments)]
pub fn im2col_slice_into(
    xd: &[f32],
    shape: &[usize],
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    out: &mut Vec<f32>,
) -> Im2colShape {
    let s = im2col_shape(shape, kh, kw, stride, pad_h, pad_w);
    let (b, c) = (shape[0], shape[1]);
    let (h, w) = (shape[2], shape[3]);
    debug_assert_eq!(xd.len(), b * c * h * w, "data length vs shape {shape:?}");
    let (oh, ow) = (s.out_h, s.out_w);
    let k = s.k;
    out.resize(s.rows * k, 0.0);

    if pad_h == 0 && pad_w == 0 {
        // Fast path: every tap is in bounds — contiguous row copies.
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = ((bi * oh + oy) * ow + ox) * k;
                    let (iy0, ix0) = (oy * stride, ox * stride);
                    let mut col = 0;
                    for ci in 0..c {
                        let base = ((bi * c + ci) * h + iy0) * w + ix0;
                        for dy in 0..kh {
                            let src = base + dy * w;
                            out[row + col..row + col + kw]
                                .copy_from_slice(&xd[src..src + kw]);
                            col += kw;
                        }
                    }
                }
            }
        }
    } else {
        // Padded path: out-of-bounds taps read as zero. Every slot is
        // written, so a reused buffer never leaks stale values.
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = ((bi * oh + oy) * ow + ox) * k;
                    let (iy0, ix0) = (oy * stride, ox * stride);
                    let mut col = 0;
                    for ci in 0..c {
                        let base = (bi * c + ci) * h * w;
                        for dy in 0..kh {
                            let iy = iy0 + dy;
                            for dx in 0..kw {
                                let ix = ix0 + dx;
                                out[row + col] = if iy < pad_h
                                    || iy >= h + pad_h
                                    || ix < pad_w
                                    || ix >= w + pad_w
                                {
                                    0.0
                                } else {
                                    xd[base + (iy - pad_h) * w + (ix - pad_w)]
                                };
                                col += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    s
}

/// Streaming row-strip extraction: materialise only patch rows
/// `row0 .. row0 + nrows` of the full `(B·OH·OW, K)` matrix into `out`
/// (resized to `nrows * k` and fully overwritten).
///
/// This is the tile feed of the blocked engine kernel
/// ([`crate::accel::ConvEngine`]): instead of building the whole patch
/// matrix up front, each shard streams one small L1-resident strip per
/// row tile. Row `row0 + i` of the strip holds exactly the values the
/// full-matrix path would place at row `row0 + i` — copies of the same
/// input elements in the same `(c, dy, dx)` order — so consuming strips
/// is bit-identical to consuming the full matrix.
#[allow(clippy::too_many_arguments)]
pub fn im2col_rows_into(
    xd: &[f32],
    shape: &[usize],
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    row0: usize,
    nrows: usize,
    out: &mut Vec<f32>,
) -> Im2colShape {
    let s = im2col_shape(shape, kh, kw, stride, pad_h, pad_w);
    assert!(
        row0 + nrows <= s.rows,
        "row strip {row0}+{nrows} out of range ({} rows)",
        s.rows
    );
    let (c, h, w) = (shape[1], shape[2], shape[3]);
    debug_assert_eq!(xd.len(), shape[0] * c * h * w, "data length vs shape {shape:?}");
    let (oh, ow) = (s.out_h, s.out_w);
    let k = s.k;
    out.resize(nrows * k, 0.0);

    for i in 0..nrows {
        // global row index → (batch, output y, output x)
        let r = row0 + i;
        let bi = r / (oh * ow);
        let rem = r % (oh * ow);
        let (oy, ox) = (rem / ow, rem % ow);
        let row = i * k;
        let (iy0, ix0) = (oy * stride, ox * stride);
        let mut col = 0;
        if pad_h == 0 && pad_w == 0 {
            // Fast path: every tap is in bounds — contiguous row copies.
            for ci in 0..c {
                let base = ((bi * c + ci) * h + iy0) * w + ix0;
                for dy in 0..kh {
                    let src = base + dy * w;
                    out[row + col..row + col + kw].copy_from_slice(&xd[src..src + kw]);
                    col += kw;
                }
            }
        } else {
            // Padded path: out-of-bounds taps read as zero. Every slot
            // is written, so a reused strip never leaks stale values.
            for ci in 0..c {
                let base = (bi * c + ci) * h * w;
                for dy in 0..kh {
                    let iy = iy0 + dy;
                    for dx in 0..kw {
                        let ix = ix0 + dx;
                        out[row + col] =
                            if iy < pad_h || iy >= h + pad_h || ix < pad_w || ix >= w + pad_w {
                                0.0
                            } else {
                                xd[base + (iy - pad_h) * w + (ix - pad_w)]
                            };
                        col += 1;
                    }
                }
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_patch_identity() {
        // kernel as large as the input → one patch per (b, c) in (c,dy,dx) order
        let x = Tensor::new(&[1, 2, 2, 2], (0..8).map(|v| v as f32).collect());
        let ic = im2col(&x, 2, 2);
        assert_eq!(ic.patches.shape(), &[1, 8]);
        assert_eq!(ic.patches.data(), &[0., 1., 2., 3., 4., 5., 6., 7.]);
        assert_eq!((ic.out_h, ic.out_w), (1, 1));
    }

    #[test]
    fn ordering_c_dy_dx() {
        // 1 channel 3x3 input, 2x2 kernel → 4 patches
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32).collect());
        let ic = im2col(&x, 2, 2);
        assert_eq!(ic.patches.shape(), &[4, 4]);
        // patch at (oy=0, ox=0): rows [0,1], cols [0,1] → 0,1,3,4
        assert_eq!(&ic.patches.data()[0..4], &[0., 1., 3., 4.]);
        // patch at (oy=1, ox=1): 4,5,7,8
        assert_eq!(&ic.patches.data()[12..16], &[4., 5., 7., 8.]);
    }

    #[test]
    fn batch_rows_ordered() {
        let x = Tensor::new(&[2, 1, 2, 2], (0..8).map(|v| v as f32).collect());
        let ic = im2col(&x, 2, 2);
        assert_eq!(ic.patches.shape(), &[2, 4]);
        assert_eq!(&ic.patches.data()[0..4], &[0., 1., 2., 3.]);
        assert_eq!(&ic.patches.data()[4..8], &[4., 5., 6., 7.]);
    }

    #[test]
    #[should_panic(expected = "larger than input")]
    fn oversized_kernel_panics() {
        let x = Tensor::zeros(&[1, 1, 2, 2]);
        im2col(&x, 3, 3);
    }

    #[test]
    fn stride_skips_positions() {
        // 1x1x5x5, 3x3 kernel, stride 2 → 2x2 output grid
        let x = Tensor::new(&[1, 1, 5, 5], (0..25).map(|v| v as f32).collect());
        let ic = im2col_geo(&x, 3, 3, 2, 0, 0);
        assert_eq!((ic.out_h, ic.out_w), (2, 2));
        // patch at (oy=0, ox=1) starts at input column 2
        assert_eq!(&ic.patches.data()[9..12], &[2., 3., 4.]);
        // patch at (oy=1, ox=0) starts at input row 2
        assert_eq!(&ic.patches.data()[18..21], &[10., 11., 12.]);
    }

    #[test]
    fn padding_reads_zeros() {
        // 1x1x2x2, 3x3 kernel, pad 1 → 2x2 output; corner patch sees 5 zeros
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let ic = im2col_geo(&x, 3, 3, 1, 1, 1);
        assert_eq!((ic.out_h, ic.out_w), (2, 2));
        // patch at (0,0): padded border on top and left
        assert_eq!(
            &ic.patches.data()[0..9],
            &[0., 0., 0., 0., 1., 2., 0., 3., 4.]
        );
    }

    #[test]
    fn asymmetric_padding_pads_each_axis_independently() {
        // pad_h 1, pad_w 0 on a 2x3 input with a 3x3 kernel: rows are
        // padded, columns are not → 2x1 output grid
        let x = Tensor::new(&[1, 1, 2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let ic = im2col_geo(&x, 3, 3, 1, 1, 0);
        assert_eq!((ic.out_h, ic.out_w), (2, 1));
        // patch at (0,0): zero top row, then the two real rows
        assert_eq!(&ic.patches.data()[0..9], &[0., 0., 0., 1., 2., 3., 4., 5., 6.]);
        // patch at (1,0): the two real rows, then a zero bottom row
        assert_eq!(&ic.patches.data()[9..18], &[1., 2., 3., 4., 5., 6., 0., 0., 0.]);
        // and the transpose case: pad_w only
        let xt = Tensor::new(&[1, 1, 3, 2], vec![1., 4., 2., 5., 3., 6.]);
        let it = im2col_geo(&xt, 3, 3, 1, 0, 1);
        assert_eq!((it.out_h, it.out_w), (1, 2));
        assert_eq!(&it.patches.data()[0..9], &[0., 1., 4., 0., 2., 5., 0., 3., 6.]);
    }

    #[test]
    fn pad_stride_zero_equals_original() {
        let x = Tensor::new(&[2, 3, 6, 5], (0..180).map(|v| v as f32 * 0.5).collect());
        let a = im2col(&x, 3, 2);
        let b = im2col_geo(&x, 3, 2, 1, 0, 0);
        assert_eq!(a.patches.data(), b.patches.data());
        assert_eq!((a.out_h, a.out_w), (b.out_h, b.out_w));
    }

    #[test]
    fn row_strips_match_full_matrix() {
        // every (geometry, strip placement) agrees element-for-element
        // with the corresponding rows of the full patch matrix
        let x = Tensor::new(&[2, 3, 7, 6], (0..252).map(|v| v as f32 * 0.25 - 13.0).collect());
        for (kh, kw, stride, ph, pw) in [
            (3, 3, 1, 0, 0),
            (3, 2, 2, 0, 0),
            (3, 3, 1, 1, 1),
            (5, 5, 2, 2, 2),
            (3, 5, 1, 1, 2),
            (5, 2, 2, 2, 0),
        ] {
            let mut full = Vec::new();
            let s = im2col_into(&x, kh, kw, stride, ph, pw, &mut full);
            let mut strip = vec![77.0; 3]; // stale garbage must be overwritten
            for nrows in [1usize, 3, s.rows] {
                let mut row0 = 0;
                while row0 < s.rows {
                    let n = nrows.min(s.rows - row0);
                    let got = im2col_rows_into(
                        x.data(), x.shape(), kh, kw, stride, ph, pw, row0, n, &mut strip,
                    );
                    assert_eq!(got, s);
                    assert_eq!(
                        &strip[..n * s.k],
                        &full[row0 * s.k..(row0 + n) * s.k],
                        "strip [{row0}, {row0}+{n}) diverged (k{kh}x{kw} s{stride} p{ph}x{pw})"
                    );
                    row0 += n;
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn row_strip_past_end_panics() {
        let x = Tensor::zeros(&[1, 1, 3, 3]);
        let mut strip = Vec::new();
        im2col_rows_into(x.data(), x.shape(), 2, 2, 1, 0, 0, 3, 2, &mut strip);
    }

    #[test]
    fn into_buffer_reuse_overwrites_fully() {
        let mut buf = vec![99.0; 4];
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32).collect());
        let s = im2col_into(&x, 2, 2, 1, 0, 0, &mut buf);
        assert_eq!(s.rows * s.k, 16);
        assert_eq!(buf.len(), 16);
        let first = buf.clone();
        // second run with a padded geometry must not leak stale values
        let s2 = im2col_into(&x, 3, 3, 1, 1, 1, &mut buf);
        assert_eq!(buf.len(), s2.rows * s2.k);
        assert_eq!(buf[0], 0.0); // padded corner
        // and back again reproduces the first result exactly
        im2col_into(&x, 2, 2, 1, 0, 0, &mut buf);
        assert_eq!(&buf[..16], &first[..]);
    }
}
