//! im2col patch extraction — the layout contract shared with the python
//! kernels (`python/compile/kernels/ref.py::im2col`): the patch axis is
//! ordered `(c, dy, dx)`, exactly the order `w.reshape(Cout, -1)` produces
//! from OIHW weights. Both the dense engine and the subtractor unit index
//! patches with the same flat weight index, so the orders must agree.

use super::Tensor;

/// Result of patch extraction: a `(B*OH*OW, K)` matrix plus geometry.
pub struct Im2col {
    /// `(rows, k)` patch matrix, row-major.
    pub patches: Tensor,
    pub batch: usize,
    pub out_h: usize,
    pub out_w: usize,
    /// K = C·kh·kw.
    pub k: usize,
}

/// Extract valid-convolution patches from an NCHW tensor.
///
/// `x`: `(B, C, H, W)` → rows ordered `(b, oy, ox)`, columns ordered
/// `(c, dy, dx)`.
pub fn im2col(x: &Tensor, kh: usize, kw: usize) -> Im2col {
    let s = x.shape();
    assert_eq!(s.len(), 4, "im2col expects NCHW, got {:?}", s);
    let (b, c, h, w) = (s[0], s[1], s[2], s[3]);
    assert!(h >= kh && w >= kw, "kernel {kh}x{kw} larger than input {h}x{w}");
    let (oh, ow) = (h - kh + 1, w - kw + 1);
    let k = c * kh * kw;
    let rows = b * oh * ow;
    let mut out = vec![0f32; rows * k];
    let xd = x.data();

    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((bi * oh + oy) * ow + ox) * k;
                let mut col = 0;
                for ci in 0..c {
                    let base = ((bi * c + ci) * h + oy) * w + ox;
                    for dy in 0..kh {
                        let src = base + dy * w;
                        out[row + col..row + col + kw]
                            .copy_from_slice(&xd[src..src + kw]);
                        col += kw;
                    }
                }
            }
        }
    }
    Im2col { patches: Tensor::new(&[rows, k], out), batch: b, out_h: oh, out_w: ow, k }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_patch_identity() {
        // kernel as large as the input → one patch per (b, c) in (c,dy,dx) order
        let x = Tensor::new(&[1, 2, 2, 2], (0..8).map(|v| v as f32).collect());
        let ic = im2col(&x, 2, 2);
        assert_eq!(ic.patches.shape(), &[1, 8]);
        assert_eq!(ic.patches.data(), &[0., 1., 2., 3., 4., 5., 6., 7.]);
        assert_eq!((ic.out_h, ic.out_w), (1, 1));
    }

    #[test]
    fn ordering_c_dy_dx() {
        // 1 channel 3x3 input, 2x2 kernel → 4 patches
        let x = Tensor::new(&[1, 1, 3, 3], (0..9).map(|v| v as f32).collect());
        let ic = im2col(&x, 2, 2);
        assert_eq!(ic.patches.shape(), &[4, 4]);
        // patch at (oy=0, ox=0): rows [0,1], cols [0,1] → 0,1,3,4
        assert_eq!(&ic.patches.data()[0..4], &[0., 1., 3., 4.]);
        // patch at (oy=1, ox=1): 4,5,7,8
        assert_eq!(&ic.patches.data()[12..16], &[4., 5., 7., 8.]);
    }

    #[test]
    fn batch_rows_ordered() {
        let x = Tensor::new(&[2, 1, 2, 2], (0..8).map(|v| v as f32).collect());
        let ic = im2col(&x, 2, 2);
        assert_eq!(ic.patches.shape(), &[2, 4]);
        assert_eq!(&ic.patches.data()[0..4], &[0., 1., 2., 3.]);
        assert_eq!(&ic.patches.data()[4..8], &[4., 5., 6., 7.]);
    }

    #[test]
    #[should_panic(expected = "larger than input")]
    fn oversized_kernel_panics() {
        let x = Tensor::zeros(&[1, 1, 2, 2]);
        im2col(&x, 3, 3);
    }
}
