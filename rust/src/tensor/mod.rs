//! Minimal dense f32 tensor substrate for the pure-rust CNN engine.
//!
//! Deliberately small: row-major contiguous storage, shape/stride
//! bookkeeping, and the handful of views the [`crate::nn`] engine and the
//! [`crate::accel`] subtractor unit need (`im2col` chief among them).
//! Everything heavier runs through the AOT/PJRT path.

mod im2col;

pub use im2col::{
    im2col, im2col_geo, im2col_into, im2col_rows_into, im2col_shape, im2col_slice_into, Im2col,
    Im2colShape,
};

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from a shape and backing data. Panics if sizes disagree.
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(
            n,
            data.len(),
            "shape {:?} needs {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape without copying. Panics if the element count changes.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        self.shape = shape.to_vec();
        self
    }

    /// Flat offset of a multi-index. Debug-asserted bounds.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < d, "index {x} out of bounds for dim {i} ({d})");
            off = off * d + x;
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let o = self.offset(idx);
        &mut self.data[o]
    }

    /// Element-wise map (new tensor).
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Maximum absolute difference against another tensor of equal shape.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Argmax over the last axis for a 2-D tensor `(rows, cols)`.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2, "argmax_rows needs a 2-D tensor");
        let (r, c) = (self.shape[0], self.shape[1]);
        (0..r)
            .map(|i| {
                let row = &self.data[i * c..(i + 1) * c];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap()
            })
            .collect()
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{} elems]", self.data.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_and_indexing() {
        let t = Tensor::new(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.offset(&[1, 0]), 3);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_size_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(&[2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
    }

    #[test]
    fn map_and_diff() {
        let t = Tensor::new(&[3], vec![1., -2., 3.]);
        let u = t.map(f32::abs);
        assert_eq!(u.data(), &[1., 2., 3.]);
        assert_eq!(t.max_abs_diff(&u), 4.0);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.9, 0.2, 5.0, -1.0, 4.9]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn zeros_full() {
        assert_eq!(Tensor::zeros(&[2, 2]).data(), &[0.0; 4]);
        assert_eq!(Tensor::full(&[3], 2.5).data(), &[2.5; 3]);
    }
}
