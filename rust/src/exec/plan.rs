//! Stage 2 + 3 of the plan/execute split: geometry-resolved
//! [`ExecutionPlan`]s and the zero-alloc [`PlanExecutor`].

use std::sync::Arc;
use std::time::Instant;

use super::{CompiledLayer, CompiledNet};
use crate::accel::{
    autotune_conv, AutotuneBudget, ConvEngine, SubConv2d, TileCache, TileDecision, TileSource,
};
use crate::error::SubaccelError;
use crate::nn::layers::{avgpool_into, dense_into, maxpool_into, Activation};
use crate::nn::{ForwardCounts, Model, OpCounts};
use crate::tensor::Tensor;

fn bad_input(reason: String) -> SubaccelError {
    SubaccelError::InvalidConfig { field: "input_shape", reason }
}

fn dims4(shape: &[usize], layer: &str) -> Result<[usize; 4], SubaccelError> {
    match *shape {
        [b, c, h, w] => Ok([b, c, h, w]),
        _ => Err(bad_input(format!("layer {layer} expects NCHW input, got {shape:?}"))),
    }
}

fn act_elems(act: Activation, n: usize) -> u64 {
    if act == Activation::None {
        0
    } else {
        n as u64
    }
}

/// One geometry-resolved step of an [`ExecutionPlan`]: the op to run,
/// its input/output shapes, and its statically known [`OpCounts`].
#[derive(Debug, Clone)]
pub struct PlanStep {
    name: String,
    in_shape: Vec<usize>,
    out_shape: Vec<usize>,
    counts: OpCounts,
    op: StepOp,
}

impl PlanStep {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn in_shape(&self) -> &[usize] {
        &self.in_shape
    }

    pub fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }

    /// Op counts for this step, known at plan-compile time (activation
    /// included) — identical to what the dynamic per-layer path counted.
    pub fn counts(&self) -> OpCounts {
        self.counts
    }

    /// The autotuned row tile for a conv step (`None` before
    /// [`ExecutionPlan::autotune`] ran, when the engine override made
    /// tuning moot, or for non-conv steps). Passed to the engine as a
    /// per-call tile on every forward.
    pub fn tile_rows(&self) -> Option<usize> {
        match &self.op {
            StepOp::PairedConv { tile, .. } => *tile,
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
enum StepOp {
    PairedConv {
        unit: Arc<SubConv2d>,
        act: Activation,
        /// Plan-warm autotuned row tile ([`ExecutionPlan::autotune`]);
        /// `None` → the engine's own override/heuristic chain.
        tile: Option<usize>,
    },
    AvgPool { k: usize, act: Activation },
    MaxPool { k: usize, stride: usize, pad: usize, act: Activation },
    /// Pure NCHW → (N, C·H·W) relabel: row-major layout is unchanged, so
    /// the executor moves no data for this step.
    Reshape { act: Activation },
    Dense { weight: Arc<Tensor>, bias: Arc<Tensor>, act: Activation },
}

/// A [`CompiledNet`] resolved against one concrete input shape: every
/// step's geometry validated, output shape and op counts precomputed,
/// scratch arena sized. Turn it into a runnable [`PlanExecutor`] with
/// [`ExecutionPlan::into_executor`].
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    name: String,
    rounding: f32,
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
    steps: Vec<PlanStep>,
    /// Largest activation buffer (elements) any step reads or writes —
    /// the size of each ping-pong scratch buffer.
    max_elems: usize,
    /// Tile decisions recorded by [`ExecutionPlan::autotune`] — `Some`
    /// makes later autotune calls no-ops (the one-shot contract).
    autotune: Option<Vec<TileDecision>>,
}

impl ExecutionPlan {
    /// One-shot convenience: Algorithm 1 + geometry resolution in a
    /// single call. Prefer compiling a [`CompiledNet`] once and planning
    /// it per shape when serving multiple batch sizes.
    pub fn compile(model: &Model, rounding: f32, input: &[usize]) -> Result<Self, SubaccelError> {
        CompiledNet::try_compile(model, rounding)?.plan(input)
    }

    pub(super) fn from_net(net: &CompiledNet, input: &[usize]) -> Result<Self, SubaccelError> {
        if input.is_empty() {
            return Err(bad_input("empty input shape".to_string()));
        }
        if let Some(d) = input.iter().position(|&n| n == 0) {
            return Err(bad_input(format!("input shape {input:?} has zero dim at axis {d}")));
        }
        let mut shape = input.to_vec();
        let mut max_elems: usize = shape.iter().product();
        let mut steps = Vec::with_capacity(net.layers.len());
        for layer in &net.layers {
            let in_shape = shape.clone();
            let (name, out_shape, counts, op) = match layer {
                CompiledLayer::Conv { name, unit, act } => {
                    let [b, c, h, w] = dims4(&in_shape, name)?;
                    let geo = unit.geometry();
                    let packed = unit.packed();
                    let (hp, wp) = (h + 2 * geo.pad_h, w + 2 * geo.pad_w);
                    if hp < geo.kh || wp < geo.kw {
                        return Err(bad_input(format!(
                            "layer {name}: kernel {}x{} larger than padded input {h}x{w}",
                            geo.kh, geo.kw
                        )));
                    }
                    if c % geo.groups != 0 {
                        return Err(bad_input(format!(
                            "layer {name}: {c} input channels not divisible into {} groups",
                            geo.groups
                        )));
                    }
                    // per-group patch length must match the packed tables
                    let k = (c / geo.groups) * geo.kh * geo.kw;
                    if k != packed.k_len() {
                        return Err(SubaccelError::KernelMismatch {
                            expected_k: packed.k_len(),
                            got_k: k,
                        });
                    }
                    let oh = (hp - geo.kh) / geo.stride + 1;
                    let ow = (wp - geo.kw) / geo.stride + 1;
                    let cout = packed.cout();
                    let rows = (b * oh * ow) as u64;
                    let mut counts = OpCounts::paired_layer(
                        packed.total_pairs() as u64,
                        packed.total_unpaired() as u64,
                        rows,
                        rows * cout as u64,
                    );
                    counts.activations += act_elems(*act, b * cout * oh * ow);
                    let op = StepOp::PairedConv { unit: unit.clone(), act: *act, tile: None };
                    (name, vec![b, cout, oh, ow], counts, op)
                }
                CompiledLayer::AvgPool { name, k, act } => {
                    let [b, c, h, w] = dims4(&in_shape, name)?;
                    let k = *k;
                    if h % k != 0 || w % k != 0 {
                        return Err(bad_input(format!("layer {name}: avgpool {k} on {h}x{w}")));
                    }
                    let (oh, ow) = (h / k, w / k);
                    let out = b * c * oh * ow;
                    let mut counts = OpCounts {
                        adds: (out * (k * k - 1)) as u64,
                        muls: out as u64,
                        ..Default::default()
                    };
                    counts.activations += act_elems(*act, out);
                    (name, vec![b, c, oh, ow], counts, StepOp::AvgPool { k, act: *act })
                }
                CompiledLayer::MaxPool { name, k, stride, pad, act } => {
                    let [b, c, h, w] = dims4(&in_shape, name)?;
                    let (k, stride, pad) = (*k, *stride, *pad);
                    if k == 0 || stride == 0 {
                        return Err(SubaccelError::InvalidConfig {
                            field: "maxpool",
                            reason: format!(
                                "layer {name}: kernel {k} / stride {stride} must be at least 1"
                            ),
                        });
                    }
                    if pad >= k {
                        return Err(SubaccelError::InvalidConfig {
                            field: "maxpool",
                            reason: format!(
                                "layer {name}: pad {pad} must be smaller than kernel {k}"
                            ),
                        });
                    }
                    if h + 2 * pad < k || w + 2 * pad < k {
                        return Err(bad_input(format!(
                            "layer {name}: maxpool kernel {k} larger than padded input \
                             {h}x{w} (pad {pad})"
                        )));
                    }
                    let oh = (h + 2 * pad - k) / stride + 1;
                    let ow = (w + 2 * pad - k) / stride + 1;
                    let mut counts = OpCounts::default();
                    counts.activations += act_elems(*act, b * c * oh * ow);
                    let op = StepOp::MaxPool { k, stride, pad, act: *act };
                    (name, vec![b, c, oh, ow], counts, op)
                }
                CompiledLayer::Flatten { name, act } => {
                    if in_shape.is_empty() {
                        return Err(bad_input(format!("layer {name}: flatten of scalar input")));
                    }
                    let rest: usize = in_shape[1..].iter().product();
                    let mut counts = OpCounts::default();
                    counts.activations += act_elems(*act, in_shape[0] * rest);
                    let out_shape = vec![in_shape[0], rest];
                    (name, out_shape, counts, StepOp::Reshape { act: *act })
                }
                CompiledLayer::Dense { name, weight, bias, act } => {
                    let (bs, nin) = match in_shape[..] {
                        [bs, nin] => (bs, nin),
                        _ => {
                            return Err(bad_input(format!(
                                "layer {name} expects (B, In) input, got {in_shape:?}"
                            )))
                        }
                    };
                    let (nout, win) = (weight.shape()[0], weight.shape()[1]);
                    if nin != win {
                        return Err(bad_input(format!(
                            "layer {name}: dense in-features {nin} vs weight {win}"
                        )));
                    }
                    let mut counts = OpCounts::dense_layer(
                        (nout * win) as u64,
                        bs as u64,
                        (bs * nout) as u64,
                    );
                    counts.activations += act_elems(*act, bs * nout);
                    let op = StepOp::Dense {
                        weight: weight.clone(),
                        bias: bias.clone(),
                        act: *act,
                    };
                    (name, vec![bs, nout], counts, op)
                }
            };
            max_elems = max_elems.max(out_shape.iter().product());
            steps.push(PlanStep {
                name: name.clone(),
                in_shape,
                out_shape: out_shape.clone(),
                counts,
                op,
            });
            shape = out_shape;
        }
        Ok(Self {
            name: net.name().to_string(),
            rounding: net.rounding(),
            input_shape: input.to_vec(),
            output_shape: shape,
            steps,
            max_elems,
            autotune: None,
        })
    }

    /// One-shot bounded row-tile sweep over the plan's conv steps
    /// ([`crate::accel::autotune`]): each step's winner is recorded in
    /// the step (passed to the engine as a per-call tile from then on)
    /// and returned as [`TileDecision`]s for trajectory persistence.
    ///
    /// Precedence per step, highest first: the engine's
    /// `SUBACCEL_TILE_ROWS`/`with_tile_rows` hard override (sweep
    /// skipped, step tile left unset — the engine override wins at
    /// forward time anyway), then a [`TileCache`] warm-start hit, then
    /// this run's sweep, then the engine heuristic.
    ///
    /// **Idempotent**: the first call sweeps and records; every later
    /// call returns the recorded decisions untouched, so repeated
    /// `warm()`s can never flap between tiles mid-serving — and since
    /// the tile only regroups independent output elements, even a
    /// *different* decision would be bit-identical
    /// (`rust/tests/prop_autotune.rs`).
    pub fn autotune(
        &mut self,
        engine: &ConvEngine,
        budget: &AutotuneBudget,
        cache: Option<&TileCache>,
    ) -> &[TileDecision] {
        if self.autotune.is_none() {
            let mut decisions = Vec::new();
            let plan_name = self.name.clone();
            for step in &mut self.steps {
                let StepOp::PairedConv { unit, tile, .. } = &mut step.op else { continue };
                let cached = if engine.tile_rows().is_none() {
                    cache.and_then(|c| c.get(&TileCache::key(&plan_name, &step.name)))
                } else {
                    None
                };
                let d = match cached {
                    Some(t) => TileDecision {
                        layer: step.name.clone(),
                        tile_rows: t,
                        source: TileSource::WarmStart,
                        score: 0.0,
                        candidates: 0,
                    },
                    None => autotune_conv(
                        engine,
                        unit.packed(),
                        unit.bias().data(),
                        unit.geometry(),
                        &step.in_shape,
                        &step.name,
                        budget,
                    ),
                };
                if engine.tile_rows().is_none() {
                    *tile = Some(d.tile_rows);
                }
                decisions.push(d);
            }
            self.autotune = Some(decisions);
        }
        self.autotune.as_deref().unwrap_or_default()
    }

    /// The recorded tile decisions, if [`ExecutionPlan::autotune`] ran.
    pub fn tile_decisions(&self) -> Option<&[TileDecision]> {
        self.autotune.as_deref()
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    /// Batch size the plan was resolved for. `from_net` rejects empty
    /// and zero-dim input shapes with a typed error, so a constructed
    /// plan always has a real leading batch dimension.
    pub fn batch(&self) -> usize {
        self.input_shape[0]
    }

    pub fn steps(&self) -> &[PlanStep] {
        &self.steps
    }

    /// Elements in each of the executor's two scratch buffers.
    pub fn scratch_elems(&self) -> usize {
        self.max_elems
    }

    /// Total combined pairs across the plan's conv steps.
    pub fn total_pairs(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match &s.op {
                StepOp::PairedConv { unit, .. } => unit.total_pairs(),
                _ => 0,
            })
            .sum()
    }

    /// The whole pass's per-layer op accounting — statically known, so
    /// executors return it without counting anything at run time.
    pub fn counts(&self) -> ForwardCounts {
        let mut fc = ForwardCounts::default();
        for s in &self.steps {
            fc.push(&s.name, s.counts);
        }
        fc
    }

    /// Stage 3: attach ping-pong scratch buffers, producing a runnable
    /// executor.
    pub fn into_executor(self) -> PlanExecutor {
        PlanExecutor { plan: self, cur: Vec::new(), spare: Vec::new() }
    }
}

/// Runs an [`ExecutionPlan`] over a shared [`ConvEngine`], reusing two
/// ping-pong activation buffers across steps and across calls: after the
/// first (warm-up) forward, `forward_into` performs **zero** heap
/// allocations (`rust/tests/alloc_plan.rs` counts them).
///
/// Not `Sync` by design — an executor is the per-replica mutable state;
/// share the engine, not the executor.
#[derive(Debug, Clone)]
pub struct PlanExecutor {
    plan: ExecutionPlan,
    cur: Vec<f32>,
    spare: Vec<f32>,
}

impl PlanExecutor {
    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Pre-grow both scratch buffers to the plan's arena size, so even
    /// the first forward performs no activation-buffer growth. (The
    /// engine's own scratch — the streaming im2col strip, `tile · k_len`
    /// floats rather than a full patch matrix, plus the row-major
    /// intermediate — still warms on the first call through a given
    /// [`ConvEngine`].)
    pub fn warm(&mut self) {
        let n = self.plan.max_elems;
        self.cur.resize(n, 0.0);
        self.spare.resize(n, 0.0);
    }

    /// [`PlanExecutor::warm`] plus the one-shot row-tile autotune sweep
    /// ([`ExecutionPlan::autotune`]). All sweep allocation happens here,
    /// at warm time — steady-state forwards stay zero-alloc
    /// (`rust/tests/alloc_plan.rs`). Idempotent: repeated calls reuse
    /// the recorded decisions.
    pub fn warm_autotuned(
        &mut self,
        engine: &ConvEngine,
        budget: &AutotuneBudget,
        cache: Option<&TileCache>,
    ) -> &[TileDecision] {
        self.warm();
        self.plan.autotune(engine, budget, cache)
    }

    /// The plan's recorded tile decisions, if a sweep ran.
    pub fn tile_decisions(&self) -> Option<&[TileDecision]> {
        self.plan.tile_decisions()
    }

    /// Run the whole network, writing logits into `out` (resized and
    /// fully overwritten); returns the output shape. Steady-state
    /// allocation-free once `out` and the scratch buffers are warm.
    pub fn forward_into(
        &mut self,
        engine: &ConvEngine,
        x: &Tensor,
        out: &mut Vec<f32>,
    ) -> Result<&[usize], SubaccelError> {
        self.run_steps(engine, x, |_, _| {})?;
        out.clear();
        out.extend_from_slice(&self.cur);
        Ok(&self.plan.output_shape)
    }

    /// Run the plan and allocate the result tensor plus the (static)
    /// per-layer counts — the drop-in equivalent of the old dynamic
    /// `PairedModel::forward_with`.
    pub fn forward(
        &mut self,
        engine: &ConvEngine,
        x: &Tensor,
    ) -> Result<(Tensor, ForwardCounts), SubaccelError> {
        let y = self.infer(engine, x)?;
        Ok((y, self.plan.counts()))
    }

    /// Run the plan, allocating only the result tensor.
    pub fn infer(&mut self, engine: &ConvEngine, x: &Tensor) -> Result<Tensor, SubaccelError> {
        self.run_steps(engine, x, |_, _| {})?;
        Ok(Tensor::new(&self.plan.output_shape, self.cur.clone()))
    }

    /// Per-step wall-clock profile `(name, seconds, counts)` — the
    /// plan-level instrumentation hook behind the Fig-1 style
    /// measurements. Counts are the plan's static ones.
    pub fn profile(
        &mut self,
        engine: &ConvEngine,
        x: &Tensor,
    ) -> Result<Vec<(String, f64, OpCounts)>, SubaccelError> {
        let mut secs = vec![0.0f64; self.plan.steps.len()];
        self.run_steps(engine, x, |i, s| secs[i] = s)?;
        Ok(self
            .plan
            .steps
            .iter()
            .zip(secs)
            .map(|(st, s)| (st.name.clone(), s, st.counts))
            .collect())
    }

    /// The shared step loop. `tick` observes `(step index, seconds)` —
    /// a no-op closure for plain forwards, a recorder for `profile`.
    fn run_steps(
        &mut self,
        engine: &ConvEngine,
        x: &Tensor,
        mut tick: impl FnMut(usize, f64),
    ) -> Result<(), SubaccelError> {
        if x.shape() != self.plan.input_shape.as_slice() {
            return Err(SubaccelError::BadShape {
                expected: self.plan.input_shape.clone(),
                got: x.shape().to_vec(),
            });
        }
        self.cur.clear();
        self.cur.extend_from_slice(x.data());
        for (i, step) in self.plan.steps.iter().enumerate() {
            let t0 = Instant::now();
            match &step.op {
                StepOp::PairedConv { unit, act, tile } => {
                    engine.forward_packed_tiled_slice_into(
                        unit.packed(),
                        unit.bias().data(),
                        unit.geometry(),
                        &self.cur,
                        &step.in_shape,
                        *tile,
                        &mut self.spare,
                    )?;
                    act.apply_slice(&mut self.spare);
                    std::mem::swap(&mut self.cur, &mut self.spare);
                }
                StepOp::AvgPool { k, act } => {
                    avgpool_into(&self.cur, &step.in_shape, *k, &mut self.spare);
                    act.apply_slice(&mut self.spare);
                    std::mem::swap(&mut self.cur, &mut self.spare);
                }
                StepOp::MaxPool { k, stride, pad, act } => {
                    maxpool_into(&self.cur, &step.in_shape, *k, *stride, *pad, &mut self.spare);
                    act.apply_slice(&mut self.spare);
                    std::mem::swap(&mut self.cur, &mut self.spare);
                }
                StepOp::Reshape { act } => {
                    // relabel only — data stays in place
                    act.apply_slice(&mut self.cur);
                }
                StepOp::Dense { weight, bias, act } => {
                    dense_into(
                        &self.cur,
                        &step.in_shape,
                        weight.data(),
                        weight.shape(),
                        bias.data(),
                        &mut self.spare,
                    );
                    act.apply_slice(&mut self.spare);
                    std::mem::swap(&mut self.cur, &mut self.spare);
                }
            }
            tick(i, t0.elapsed().as_secs_f64());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{alexnet, lenet5};
    use crate::util::Rng;

    fn randt(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect())
    }

    #[test]
    fn lenet_plan_resolves_shapes_and_scratch() {
        let plan = ExecutionPlan::compile(&lenet5(), 0.1, &[2, 1, 32, 32]).unwrap();
        assert_eq!(plan.batch(), 2);
        assert_eq!(plan.output_shape(), &[2, 10]);
        assert_eq!(plan.steps().len(), 8);
        let shapes: Vec<&[usize]> = plan.steps().iter().map(|s| s.out_shape()).collect();
        assert_eq!(shapes[0], &[2, 6, 28, 28]);
        assert_eq!(shapes[4], &[2, 120, 1, 1]);
        assert_eq!(shapes[5], &[2, 120]);
        // scratch must fit the biggest activation (c1 output here)
        assert_eq!(plan.scratch_elems(), 2 * 6 * 28 * 28);
        assert!(plan.total_pairs() > 0);
    }

    #[test]
    fn static_counts_match_dynamic_dense_counts_at_zero_rounding() {
        // at rounding 0 nothing pairs, so paired counts == dense counts
        let m = lenet5();
        let plan = ExecutionPlan::compile(&m, 0.0, &[1, 1, 32, 32]).unwrap();
        let (_, dynamic) = m.forward(&Tensor::full(&[1, 1, 32, 32], 0.2));
        let static_counts = plan.counts();
        assert_eq!(static_counts.per_layer.len(), dynamic.per_layer.len());
        assert_eq!(static_counts, dynamic);
    }

    #[test]
    fn executor_reuses_buffers_across_inputs() {
        let mut rng = Rng::seed_from_u64(11);
        let mut exec =
            ExecutionPlan::compile(&lenet5(), 0.08, &[1, 1, 32, 32]).unwrap().into_executor();
        let engine = ConvEngine::serial();
        let a = randt(&mut rng, &[1, 1, 32, 32]);
        let b = randt(&mut rng, &[1, 1, 32, 32]);
        let ya1 = exec.infer(&engine, &a).unwrap();
        let _ = exec.infer(&engine, &b).unwrap();
        let ya2 = exec.infer(&engine, &a).unwrap();
        assert_eq!(ya1, ya2, "buffer reuse changed results");
    }

    #[test]
    fn plan_results_are_tile_invariant() {
        // whole-network outputs are bit-identical across row-tile sizes
        // and thread counts (the engine's tiling must be invisible here)
        let mut rng = Rng::seed_from_u64(23);
        let x = randt(&mut rng, &[2, 1, 32, 32]);
        let mut exec =
            ExecutionPlan::compile(&lenet5(), 0.05, &[2, 1, 32, 32]).unwrap().into_executor();
        let want = exec.infer(&ConvEngine::serial(), &x).unwrap();
        for tile in [1usize, 3, 8, 64, 4096] {
            let eng = ConvEngine::with_tile_rows(2, tile).unwrap();
            let got = exec.infer(&eng, &x).unwrap();
            assert_eq!(got, want, "tile {tile} diverged through the plan path");
        }
    }

    #[test]
    fn autotuned_warm_is_idempotent_and_bit_identical() {
        let mut rng = Rng::seed_from_u64(41);
        let x = randt(&mut rng, &[2, 1, 32, 32]);
        let mut plain =
            ExecutionPlan::compile(&lenet5(), 0.08, &[2, 1, 32, 32]).unwrap().into_executor();
        let engine = ConvEngine::serial();
        let want = plain.infer(&engine, &x).unwrap();

        let mut tuned =
            ExecutionPlan::compile(&lenet5(), 0.08, &[2, 1, 32, 32]).unwrap().into_executor();
        assert_eq!(tuned.tile_decisions(), None);
        let budget = AutotuneBudget::default();
        let d1 = tuned.warm_autotuned(&engine, &budget, None).to_vec();
        // one decision per conv step, each a real tile from this sweep
        assert_eq!(d1.len(), 3);
        assert!(d1.iter().all(|d| d.tile_rows >= 1 && d.source == TileSource::Autotuned));
        let tiles: Vec<_> = tuned
            .plan()
            .steps()
            .iter()
            .filter(|s| s.name().starts_with('c'))
            .map(|s| s.tile_rows())
            .collect();
        assert!(tiles.iter().all(|t| t.is_some()), "{tiles:?}");
        // repeated warms reuse the recorded decisions (one-shot contract)
        let d2 = tuned.warm_autotuned(&engine, &budget, None).to_vec();
        assert_eq!(d1, d2);
        // and the tuned plan's output is bit-identical to the untuned one
        let got = tuned.infer(&engine, &x).unwrap();
        assert_eq!(got, want, "autotuned plan diverged");
    }

    #[test]
    fn warm_start_cache_and_override_precedence() {
        let engine = ConvEngine::serial();
        let budget = AutotuneBudget::default();
        // a cache hit wins over the sweep and lands in the step
        let mut cache = crate::accel::TileCache::default();
        cache.insert(crate::accel::TileCache::key("lenet5", "c1"), 2);
        let mut exe =
            ExecutionPlan::compile(&lenet5(), 0.08, &[1, 1, 32, 32]).unwrap().into_executor();
        let d = exe.warm_autotuned(&engine, &budget, Some(&cache)).to_vec();
        assert_eq!(d[0].source, TileSource::WarmStart);
        assert_eq!(d[0].tile_rows, 2);
        assert_eq!(exe.plan().steps()[0].tile_rows(), Some(2));
        assert!(d[1..].iter().all(|x| x.source == TileSource::Autotuned));
        // an engine-wide override beats both cache and sweep, and the
        // plan leaves the step tiles unset (the engine wins at forward)
        let forced = ConvEngine::with_tile_rows(1, 7).unwrap();
        let mut exe2 =
            ExecutionPlan::compile(&lenet5(), 0.08, &[1, 1, 32, 32]).unwrap().into_executor();
        let d2 = exe2.warm_autotuned(&forced, &budget, Some(&cache)).to_vec();
        assert!(d2.iter().all(|x| x.source == TileSource::Override && x.tile_rows == 7));
        assert!(exe2.plan().steps().iter().all(|s| s.tile_rows().is_none()));
    }

    #[test]
    fn executor_rejects_wrong_input_shape() {
        let mut exec =
            ExecutionPlan::compile(&lenet5(), 0.1, &[1, 1, 32, 32]).unwrap().into_executor();
        let err = exec.infer(&ConvEngine::serial(), &Tensor::zeros(&[2, 1, 32, 32])).unwrap_err();
        assert_eq!(
            err,
            SubaccelError::BadShape { expected: vec![1, 1, 32, 32], got: vec![2, 1, 32, 32] }
        );
    }

    #[test]
    fn bad_geometry_is_a_typed_plan_error() {
        let net = CompiledNet::compile(&lenet5(), 0.1);
        // wrong channel count → kernel mismatch at c1
        match net.plan(&[1, 3, 32, 32]) {
            Err(SubaccelError::KernelMismatch { expected_k: 25, got_k: 75 }) => {}
            other => panic!("expected KernelMismatch, got {other:?}"),
        }
        // input too small for c1's 5x5 kernel
        match net.plan(&[1, 1, 4, 4]) {
            Err(SubaccelError::InvalidConfig { field: "input_shape", .. }) => {}
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn zero_dim_and_empty_inputs_are_typed_plan_errors() {
        let net = CompiledNet::compile(&lenet5(), 0.1);
        for bad in [&[][..], &[0, 1, 32, 32][..], &[1, 1, 0, 32][..], &[2, 1, 32, 0][..]] {
            match net.plan(bad) {
                Err(SubaccelError::InvalidConfig { field: "input_shape", .. }) => {}
                other => panic!("plan({bad:?}): expected InvalidConfig, got {other:?}"),
            }
        }
        // a valid plan's batch() is the real leading dim
        assert_eq!(net.plan(&[3, 1, 32, 32]).unwrap().batch(), 3);
    }

    #[test]
    fn maxpool_kernel_larger_than_input_is_typed_error() {
        use crate::nn::layers::{Layer, LayerKind};
        let pool = |k: usize, stride: usize, pad: usize| {
            Model::new(
                "pool-only",
                vec![Layer::new(
                    "p",
                    LayerKind::MaxPool { k, stride, pad },
                    Activation::None,
                )],
            )
        };
        // k > padded input → InvalidConfig instead of the historical
        // (h - k) underflow panic
        match ExecutionPlan::compile(&pool(5, 2, 0), 0.0, &[1, 1, 4, 4]) {
            Err(SubaccelError::InvalidConfig { field: "input_shape", .. }) => {}
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
        // pad ≥ k is rejected (window could float entirely in padding)
        match ExecutionPlan::compile(&pool(2, 1, 2), 0.0, &[1, 1, 4, 4]) {
            Err(SubaccelError::InvalidConfig { field: "maxpool", .. }) => {}
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
        match ExecutionPlan::compile(&pool(2, 0, 0), 0.0, &[1, 1, 4, 4]) {
            Err(SubaccelError::InvalidConfig { field: "maxpool", .. }) => {}
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
        // pad makes an otherwise-too-small input legal: 4+2·1 ≥ 5
        let plan = ExecutionPlan::compile(&pool(5, 2, 1), 0.0, &[1, 1, 4, 4]).unwrap();
        assert_eq!(plan.output_shape(), &[1, 1, 1, 1]);
    }

    #[test]
    fn grouped_mixer_plans_and_runs_through_the_engine() {
        use crate::nn::grouped_mixer;
        let m = grouped_mixer();
        let plan = ExecutionPlan::compile(&m, 0.1, &[2, 8, 20, 16]).unwrap();
        assert_eq!(plan.output_shape(), &[2, 10]);
        let shapes: Vec<&[usize]> = plan.steps().iter().map(|s| s.out_shape()).collect();
        assert_eq!(shapes[0], &[2, 16, 20, 16]);
        assert_eq!(shapes[1], &[2, 16, 10, 8]);
        assert_eq!(shapes[2], &[2, 32, 5, 4]);
        // engine path == dense model with snapped weights (tolerance:
        // different summation order), and thread/tile invariant (exact)
        let mut rng = Rng::seed_from_u64(29);
        let x = randt(&mut rng, &[2, 8, 20, 16]);
        let mut exec = plan.clone().into_executor();
        let y1 = exec.infer(&ConvEngine::serial(), &x).unwrap();
        for (threads, tile) in [(2, 1), (3, 7), (2, 4096)] {
            let eng = ConvEngine::with_tile_rows(threads, tile).unwrap();
            let got = exec.infer(&eng, &x).unwrap();
            assert_eq!(got, y1, "threads {threads} tile {tile} diverged");
        }
        let mut snapped = m.clone();
        for info in m.conv_layers(&[2, 8, 20, 16]) {
            let lp = crate::accel::LayerPairing::from_weights(&info.weight, 0.1);
            snapped.set_conv_weights(&info.name, lp.modified_weights(&info.weight));
        }
        let (want, _) = snapped.forward(&x);
        assert_eq!(y1.shape(), want.shape());
        assert!(y1.max_abs_diff(&want) < 1e-4, "{}", y1.max_abs_diff(&want));
    }

    #[test]
    fn alexnet_plan_resolves_with_maxpool_and_relu() {
        let plan = ExecutionPlan::compile(&alexnet(), 0.02, &[1, 3, 227, 227]).unwrap();
        assert_eq!(plan.output_shape(), &[1, 1000]);
        // all conv steps carry subtractions in their static counts at
        // nonzero rounding
        let convs: Vec<_> =
            plan.steps().iter().filter(|s| s.name().starts_with("conv")).collect();
        assert_eq!(convs.len(), 5);
        assert!(convs.iter().all(|s| s.counts().subs > 0));
    }

    #[test]
    fn profile_reports_every_step_with_static_counts() {
        let mut exec =
            ExecutionPlan::compile(&lenet5(), 0.1, &[1, 1, 32, 32]).unwrap().into_executor();
        let engine = ConvEngine::serial();
        let prof = exec.profile(&engine, &Tensor::full(&[1, 1, 32, 32], 0.1)).unwrap();
        assert_eq!(prof.len(), 8);
        let total: OpCounts = exec.plan().counts().total();
        let prof_total = prof.iter().fold(OpCounts::default(), |a, (_, _, c)| a + *c);
        assert_eq!(total, prof_total);
    }
}
