//! Plan/execute split for whole-network paired inference.
//!
//! The paper's premise is *pay once, serve cheap*: Algorithm 1 sorts,
//! pairs, and rounds conv weights ahead of time so the steady-state
//! inference loop runs on subtractors. This module applies the same
//! discipline to the software stack, in two compile stages and one
//! executor:
//!
//! 1. [`CompiledNet`] — shape-independent. Runs Algorithm 1 once per
//!    conv layer ([`SubConv2d`] → [`crate::accel::PackedPairing`]) and
//!    snapshots the dense layers. Compile this once per (model,
//!    rounding); it is cheap to clone (weights and pairings sit behind
//!    `Arc`s).
//! 2. [`ExecutionPlan`] — shape-resolved. [`CompiledNet::plan`] walks
//!    the layer graph for a concrete input shape, checks every
//!    geometry up front (typed [`SubaccelError`]s instead of
//!    mid-forward panics), precomputes each step's output shape and
//!    static [`OpCounts`], and sizes the scratch arena.
//! 3. [`PlanExecutor`] — owns two ping-pong activation buffers sized by
//!    the plan. Its `forward_into` runs the whole network on a shared
//!    [`crate::accel::ConvEngine`] with **zero steady-state heap
//!    allocations** (proved by `rust/tests/alloc_plan.rs`). Warming via
//!    [`PlanExecutor::warm_autotuned`] additionally runs the one-shot
//!    row-tile sweep ([`crate::accel::autotune`]) and pins each conv
//!    step's winning tile in the plan — all tuning cost and allocation
//!    lands at warm time, and the decision is recorded so trajectory
//!    reruns can warm-start instead of re-sweeping.
//!
//! All three serving paths — [`crate::nn::PairedModel`],
//! [`crate::runtime::PairedCpuLeNet5`], and the coordinator's
//! `Backend::CpuEngine` replicas — route through this one executor, so
//! they are bit-identical by construction (property-tested in
//! `rust/tests/prop_plan.rs`).

mod plan;

pub use plan::{ExecutionPlan, PlanExecutor, PlanStep};

use std::sync::Arc;

use crate::accel::{ConvGeometry, SubConv2d};
use crate::error::SubaccelError;
use crate::nn::layers::{Activation, LayerKind};
use crate::nn::Model;
use crate::tensor::Tensor;

/// Stage 1: a [`Model`] with every conv layer preprocessed by
/// Algorithm 1 at a fixed rounding size. Shape-independent — one
/// `CompiledNet` serves any batch size or spatial geometry via
/// [`CompiledNet::plan`].
#[derive(Debug, Clone)]
pub struct CompiledNet {
    name: String,
    rounding: f32,
    layers: Vec<CompiledLayer>,
}

/// One shape-independent compiled layer. Weights and pairings are
/// `Arc`-shared so plans clone handles, not buffers.
#[derive(Debug, Clone)]
enum CompiledLayer {
    /// Conv on the paired subtractor datapath.
    Conv { name: String, unit: Arc<SubConv2d>, act: Activation },
    AvgPool { name: String, k: usize, act: Activation },
    MaxPool { name: String, k: usize, stride: usize, pad: usize, act: Activation },
    Flatten { name: String, act: Activation },
    Dense { name: String, weight: Arc<Tensor>, bias: Arc<Tensor>, act: Activation },
}

impl CompiledNet {
    /// Run Algorithm 1 over every conv layer of `model` at the given
    /// rounding size. This is the expensive step (sorting weights);
    /// everything downstream reuses its output. Panics on malformed conv
    /// layers (historical API); [`CompiledNet::try_compile`] is the typed
    /// form the serving paths use.
    pub fn compile(model: &Model, rounding: f32) -> Self {
        Self::try_compile(model, rounding).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`CompiledNet::compile`] with malformed conv layers (group/kernel
    /// disagreements, zero stride, …) reported as typed
    /// [`SubaccelError`]s instead of panics.
    pub fn try_compile(model: &Model, rounding: f32) -> Result<Self, SubaccelError> {
        let mut layers = Vec::with_capacity(model.layers.len());
        for layer in &model.layers {
            let name = layer.name.clone();
            layers.push(match &layer.kind {
                LayerKind::Conv2d { weight, bias, stride, pad_h, pad_w, groups } => {
                    let geo = ConvGeometry {
                        kh: weight.shape()[2],
                        kw: weight.shape()[3],
                        stride: *stride,
                        pad_h: *pad_h,
                        pad_w: *pad_w,
                        groups: *groups,
                    };
                    let unit = SubConv2d::compile_with(weight, bias, rounding, geo)?;
                    CompiledLayer::Conv { name, unit: Arc::new(unit), act: layer.act }
                }
                LayerKind::AvgPool { k } => CompiledLayer::AvgPool { name, k: *k, act: layer.act },
                LayerKind::MaxPool { k, stride, pad } => CompiledLayer::MaxPool {
                    name,
                    k: *k,
                    stride: *stride,
                    pad: *pad,
                    act: layer.act,
                },
                LayerKind::Flatten => CompiledLayer::Flatten { name, act: layer.act },
                LayerKind::Dense { weight, bias } => CompiledLayer::Dense {
                    name,
                    weight: Arc::new(weight.clone()),
                    bias: Arc::new(bias.clone()),
                    act: layer.act,
                },
            });
        }
        Ok(Self { name: model.name.clone(), rounding, layers })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn rounding(&self) -> f32 {
        self.rounding
    }

    /// Total combined pairs across all conv layers.
    pub fn total_pairs(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                CompiledLayer::Conv { unit, .. } => unit.total_pairs(),
                _ => 0,
            })
            .sum()
    }

    /// Per-conv-layer pair counts `(name, pairs)`.
    pub fn pairs_per_conv(&self) -> Vec<(String, usize)> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                CompiledLayer::Conv { name, unit, .. } => Some((name.clone(), unit.total_pairs())),
                _ => None,
            })
            .collect()
    }

    /// Stage 2: resolve all layer geometry for a concrete input shape.
    /// Cheap (shape arithmetic + `Arc` clones); errors are typed —
    /// [`SubaccelError::InvalidConfig`] for impossible geometry,
    /// [`SubaccelError::KernelMismatch`] for channel/kernel disagreement.
    pub fn plan(&self, input: &[usize]) -> Result<ExecutionPlan, SubaccelError> {
        ExecutionPlan::from_net(self, input)
    }
}
