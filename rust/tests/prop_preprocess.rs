//! Property tests over the paper's core invariants (Algorithm 1 + the
//! subtractor conv unit), using the in-tree `forall` helper.

use subaccel::accel::{pair_filter, LayerPairing, SubConv2d};
use subaccel::nn::layers::conv2d;
use subaccel::tensor::Tensor;
use subaccel::util::{forall, Gen};

const CASES: usize = 200;

fn rand_rounding(g: &mut Gen) -> f32 {
    // mix zero, tiny, paper-range, and huge roundings
    match g.rng.below(5) {
        0 => 0.0,
        1 => g.rng.range(0.0, 0.01),
        2 => g.rng.range(0.01, 0.3),
        3 => g.rng.range(0.3, 2.0),
        _ => 1e9,
    }
}

#[test]
fn conservation_no_weight_lost_or_duplicated() {
    forall("conservation", 0xC0DE, CASES, |g| {
        let w = g.weights(300, 1.0);
        let r = rand_rounding(g);
        let p = pair_filter(&w, r);
        if 2 * p.n_pairs() + p.n_unpaired() != w.len() {
            return Err(format!("count mismatch: {} pairs, {} unpaired, {} weights", p.n_pairs(), p.n_unpaired(), w.len()));
        }
        let mut seen: Vec<u32> = p.pair_i1.iter().chain(&p.pair_i2).chain(&p.unp_idx).copied().collect();
        seen.sort_unstable();
        if seen != (0..w.len() as u32).collect::<Vec<_>>() {
            return Err("indices are not a permutation".into());
        }
        Ok(())
    });
}

#[test]
fn pairs_respect_rounding_window_and_signs() {
    forall("pair-window", 0xBEEF, CASES, |g| {
        let w = g.weights(300, 1.0);
        let r = rand_rounding(g);
        let p = pair_filter(&w, r);
        for j in 0..p.n_pairs() {
            let ka = w[p.pair_i1[j] as usize];
            let kb = w[p.pair_i2[j] as usize];
            if ka <= 0.0 || kb >= 0.0 {
                return Err(format!("pair signs wrong: {ka} {kb}"));
            }
            if (ka - (-kb)).abs() >= r {
                return Err(format!("pair outside window: |{ka} - {}| >= {r}", -kb));
            }
            let k = p.pair_k[j];
            if (k - (ka + (-kb)) / 2.0).abs() > 1e-6 {
                return Err("snap is not the mean magnitude".into());
            }
        }
        Ok(())
    });
}

#[test]
fn snap_error_bounded_by_half_rounding() {
    forall("snap-bound", 0xF00D, CASES, |g| {
        let n = 1 + g.rng.below(128);
        let cout = 1 + g.rng.below(4);
        let w = Tensor::new(&[cout, n], g.rng.vec_range(cout * n, -1.0, 1.0));
        let r = g.rng.range(0.0, 0.5);
        let p = LayerPairing::from_weights(&w, r);
        let err = p.max_snap_error(&w);
        if err > r / 2.0 + 1e-6 {
            return Err(format!("snap error {err} > rounding/2 = {}", r / 2.0));
        }
        Ok(())
    });
}

#[test]
fn pair_count_monotone_in_rounding() {
    forall("monotone", 0xAAAA, 100, |g| {
        let w = g.weights(200, 1.0);
        let mut prev = 0usize;
        for r in [0.0f32, 0.005, 0.02, 0.05, 0.1, 0.3, 1.0, 1e9] {
            let n = pair_filter(&w, r).n_pairs();
            if n < prev {
                return Err(format!("pairs dropped from {prev} to {n} at rounding {r}"));
            }
            prev = n;
        }
        // at infinite rounding everything pairable is paired
        let npos = w.iter().filter(|&&v| v > 0.0).count();
        let nneg = w.iter().filter(|&&v| v < 0.0).count();
        if prev != npos.min(nneg) {
            return Err(format!("saturation {prev} != min({npos},{nneg})"));
        }
        Ok(())
    });
}

#[test]
fn input_order_invariance() {
    // pairing depends on values, not on storage order: shuffling weights
    // yields the same multiset of (ka, kb) pairs
    forall("order-invariance", 0x5EED, 100, |g| {
        let w = g.weights(100, 1.0);
        let r = g.rng.range(0.0, 0.3);
        let mut shuffled_idx: Vec<usize> = (0..w.len()).collect();
        g.rng.shuffle(&mut shuffled_idx);
        let ws: Vec<f32> = shuffled_idx.iter().map(|&i| w[i]).collect();

        let key = |w: &[f32], p: &subaccel::accel::FilterPairing| {
            let mut v: Vec<(u32, u32)> = (0..p.n_pairs())
                .map(|j| {
                    (
                        w[p.pair_i1[j] as usize].to_bits(),
                        w[p.pair_i2[j] as usize].to_bits(),
                    )
                })
                .collect();
            v.sort_unstable();
            v
        };
        let a = pair_filter(&w, r);
        let b = pair_filter(&ws, r);
        if key(&w, &a) != key(&ws, &b) {
            return Err("pair multiset changed under shuffle".into());
        }
        Ok(())
    });
}

#[test]
fn subconv_equals_dense_modified_conv() {
    forall("subconv-equivalence", 0xD1FF, 60, |g| {
        let cin = 1 + g.rng.below(3);
        let k = 1 + g.rng.below(4);
        let extra = g.rng.below(5);
        let (h, wdt) = (k + extra, k + extra);
        let cout = 1 + g.rng.below(6);
        let x = Tensor::new(&[1, cin, h, wdt], g.rng.vec_range(cin * h * wdt, -1.0, 1.0));
        let w = Tensor::new(&[cout, cin, k, k], g.rng.vec_range(cout * cin * k * k, -1.0, 1.0));
        let b = Tensor::new(&[cout], g.rng.vec_range(cout, -0.5, 0.5));
        let r = rand_rounding(g);

        let unit = SubConv2d::compile(&w, &b, r);
        let (got, counts) = unit.forward(&x);
        let wmod = unit.pairing().modified_weights(&w);
        let (want, base) = conv2d(&x, &wmod, &b, 1, 0);
        let diff = got.max_abs_diff(&want);
        if diff > 1e-4 {
            return Err(format!("paired vs dense-modified diff {diff}"));
        }
        // Table-1 identity: sub count trades 1:1 against mul and add
        if counts.muls + counts.subs != base.muls || counts.adds + counts.subs != base.adds {
            return Err("op identity violated".into());
        }
        Ok(())
    });
}

#[test]
fn modified_weights_never_flip_signs() {
    forall("sign-preservation", 0x5164, 100, |g| {
        let w = g.weights(150, 1.0);
        let t = Tensor::new(&[1, w.len()], w.clone());
        let r = g.rng.range(0.0, 1.0);
        let m = LayerPairing::from_weights(&t, r).modified_weights(&t);
        for (a, b) in w.iter().zip(m.data()) {
            if a.signum() != b.signum() && *a != 0.0 {
                return Err(format!("sign flip {a} -> {b}"));
            }
        }
        Ok(())
    });
}
