//! Full-geometry sweep: grouped convolutions, non-square kernels,
//! asymmetric padding, and stride > 1 must agree *bit-for-bit* across
//! three independently written paths —
//!
//!   1. the tile-blocked microkernel (every tile size × thread count),
//!   2. the untiled packed reference (`forward_packed_reference`),
//!   3. a naive direct convolution over the pairing tables written here
//!      with no im2col and no tiling,
//!
//! and to 1e-4 of a dense grouped convolution over the snapped weights.
//! The naive path is bit-identical (not merely close) because it
//! reproduces the engine's per-element reduction order exactly: pair
//! lane summed in table order, then the MAC lane, then
//! `bias + pair + mac`. Tiling, sharding, and im2col only change which
//! *elements* are computed when, never the order of a single element's
//! reduction.

use subaccel::accel::{ConvEngine, LayerPairing, SubConv2d};
use subaccel::error::SubaccelError;
use subaccel::nn::layers::conv2d_into;
use subaccel::tensor::Tensor;
use subaccel::util::{forall, Gen};

/// Direct convolution over the packed pairing tables: decode each tap
/// index to (channel, dy, dx) and read the padded input directly.
/// Out-of-bounds taps read the zero padding.
fn naive_paired_conv(unit: &SubConv2d, x: &Tensor) -> Tensor {
    let geo = unit.geometry();
    let packed = unit.packed();
    let bias = unit.bias().data();
    let (batch, cin, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (kh, kw) = (geo.kh, geo.kw);
    let khw = kh * kw;
    let wcin = packed.k_len() / khw; // input channels per group
    assert_eq!(cin, wcin * geo.groups, "input channels vs grouped weights");
    let cpg = packed.cout / geo.groups; // output channels per group
    let oh = (h + 2 * geo.pad_h - kh) / geo.stride + 1;
    let ow = (w + 2 * geo.pad_w - kw) / geo.stride + 1;
    let xd = x.data();
    // one padded tap read, 0.0 outside the input
    let tap = |b: usize, ch: usize, iy: isize, ix: isize| -> f32 {
        if iy < 0 || ix < 0 || iy as usize >= h || ix as usize >= w {
            0.0
        } else {
            xd[((b * cin + ch) * h + iy as usize) * w + ix as usize]
        }
    };
    let mut out = vec![0.0f32; batch * packed.cout * oh * ow];
    for b in 0..batch {
        for c in 0..packed.cout {
            let c0 = (c / cpg) * wcin; // first input channel of c's group
            let (i1, i2, kk) = packed.pairs(c);
            let (ui, uw) = packed.unpaired(c);
            for oy in 0..oh {
                for ox in 0..ow {
                    let at = |idx: u32| {
                        let idx = idx as usize;
                        let (ci, rem) = (idx / khw, idx % khw);
                        let iy = (oy * geo.stride + rem / kw) as isize - geo.pad_h as isize;
                        let ix = (ox * geo.stride + rem % kw) as isize - geo.pad_w as isize;
                        tap(b, c0 + ci, iy, ix)
                    };
                    let mut pair_acc = 0.0f32;
                    for j in 0..kk.len() {
                        pair_acc += kk[j] * (at(i1[j]) - at(i2[j]));
                    }
                    let mut mac_acc = 0.0f32;
                    for j in 0..uw.len() {
                        mac_acc += uw[j] * at(ui[j]);
                    }
                    out[((b * packed.cout + c) * oh + oy) * ow + ox] =
                        bias[c] + pair_acc + mac_acc;
                }
            }
        }
    }
    Tensor::new(&[batch, packed.cout, oh, ow], out)
}

/// Random full-geometry conv problem: non-square kernel, possibly
/// asymmetric padding, stride 1–3, groups 1–3.
fn random_geometry(g: &mut Gen) -> (Tensor, Tensor, Tensor, f32, SubConv2d) {
    let groups = 1 + g.rng.below(3);
    let cpg = 1 + g.rng.below(3);
    let wcin = 1 + g.rng.below(2);
    let cout = groups * cpg;
    let cin = groups * wcin;
    let kh = 1 + g.rng.below(3);
    let mut kw = 1 + g.rng.below(3);
    if kw == kh {
        kw = kh % 3 + 1; // force non-square: square kernels are covered elsewhere
    }
    let stride = 1 + g.rng.below(3);
    let (pad_h, pad_w) = (g.rng.below(3), g.rng.below(3));
    let (h, w) = (kh + g.rng.below(6), kw + g.rng.below(6));
    let batch = 1 + g.rng.below(2);
    let weight = Tensor::new(&[cout, wcin, kh, kw], g.rng.vec_normal(cout * wcin * kh * kw));
    let bias = Tensor::new(&[cout], g.rng.vec_normal(cout));
    let x = Tensor::new(&[batch, cin, h, w], g.rng.vec_normal(batch * cin * h * w));
    let rounding = [0.0f32, 0.05, 0.2][g.rng.below(3)];
    let geo = subaccel::accel::ConvGeometry { kh, kw, stride, pad_h, pad_w, groups };
    let unit = SubConv2d::compile_with(&weight, &bias, rounding, geo)
        .unwrap_or_else(|e| panic!("compile_with: {e}"));
    (weight, bias, x, rounding, unit)
}

#[test]
fn geometry_sweep_tiled_untiled_naive_bit_identical() {
    let engines: Vec<ConvEngine> = [(1usize, 1usize), (2, 3), (4, 8), (3, 4096)]
        .iter()
        .map(|&(t, tile)| ConvEngine::with_tile_rows(t, tile).unwrap())
        .chain([ConvEngine::serial(), ConvEngine::new(2).unwrap()])
        .collect();
    forall("geometry-sweep", 0x6E0_2026, 40, |g| {
        let (_, _, x, _, unit) = random_geometry(g);
        let geo = unit.geometry();
        let tag = format!(
            "k {}x{} stride {} pad ({},{}) groups {}",
            geo.kh, geo.kw, geo.stride, geo.pad_h, geo.pad_w, geo.groups
        );
        let (want, want_counts) =
            ConvEngine::forward_packed_reference(unit.packed(), unit.bias(), geo, &x)
                .map_err(|e| format!("{tag}: reference: {e}"))?;
        // naive direct conv (no im2col, no tiling) — must be exact
        let naive = naive_paired_conv(&unit, &x);
        if naive != want {
            return Err(format!(
                "{tag}: naive direct conv diverged from reference (max |Δ| {})",
                naive.max_abs_diff(&want)
            ));
        }
        // every tiled/threaded engine — must be exact
        for engine in &engines {
            let (got, counts) = unit.forward_with(engine, &x).map_err(|e| {
                format!("{tag} t={} tile={:?}: {e}", engine.threads(), engine.tile_rows())
            })?;
            if got != want {
                return Err(format!(
                    "{tag} t={} tile={:?}: diverged (max |Δ| {})",
                    engine.threads(),
                    engine.tile_rows(),
                    got.max_abs_diff(&want)
                ));
            }
            if counts != want_counts {
                return Err(format!("{tag}: op counts diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn geometry_sweep_matches_dense_grouped_oracle() {
    // Pairing snaps weights but never changes the arithmetic: a dense
    // grouped convolution over the snapped weights is the independent
    // numeric oracle (different summation order, hence the tolerance).
    let engine = ConvEngine::new(3).unwrap();
    forall("geometry-dense-oracle", 0xD0_2026, 30, |g| {
        let (weight, bias, x, rounding, unit) = random_geometry(g);
        let geo = unit.geometry();
        let (got, _) = unit
            .forward_with(&engine, &x)
            .map_err(|e| format!("engine forward: {e}"))?;
        let snapped = LayerPairing::from_weights(&weight, rounding).modified_weights(&weight);
        let mut dense = Vec::new();
        let (shape, _) = conv2d_into(
            x.data(),
            x.shape(),
            snapped.data(),
            snapped.shape(),
            bias.data(),
            geo.stride,
            geo.pad_h,
            geo.pad_w,
            geo.groups,
            &mut dense,
        );
        let want = Tensor::new(&shape, dense);
        if got.shape() != want.shape() {
            return Err(format!("shape {:?} != dense {:?}", got.shape(), want.shape()));
        }
        let diff = got.max_abs_diff(&want);
        if diff > 1e-4 {
            return Err(format!(
                "k {}x{} stride {} pad ({},{}) groups {}: max |Δ| {diff} > 1e-4",
                geo.kh, geo.kw, geo.stride, geo.pad_h, geo.pad_w, geo.groups
            ));
        }
        Ok(())
    });
}

#[test]
fn invalid_geometries_are_typed_errors() {
    let mut rng = subaccel::util::Rng::seed_from_u64(9);
    let weight = Tensor::new(&[4, 2, 3, 5], rng.vec_normal(4 * 2 * 3 * 5));
    let bias = Tensor::zeros(&[4]);
    let geo = |groups: usize, stride: usize| subaccel::accel::ConvGeometry {
        kh: 3,
        kw: 5,
        stride,
        pad_h: 1,
        pad_w: 2,
        groups,
    };
    // cout = 4 not divisible by groups = 3
    match SubConv2d::compile_with(&weight, &bias, 0.1, geo(3, 1)) {
        Err(SubaccelError::InvalidConfig { field, .. }) => assert_eq!(field, "groups"),
        other => panic!("expected InvalidConfig(groups), got {other:?}"),
    }
    // stride 0
    match SubConv2d::compile_with(&weight, &bias, 0.1, geo(1, 0)) {
        Err(SubaccelError::InvalidConfig { field, .. }) => assert_eq!(field, "stride"),
        other => panic!("expected InvalidConfig(stride), got {other:?}"),
    }
    // valid grouped compile, wrong input channel count → typed K mismatch
    let unit = SubConv2d::compile_with(&weight, &bias, 0.1, geo(2, 1)).unwrap();
    let engine = ConvEngine::new(2).unwrap();
    let bad = Tensor::zeros(&[1, 3, 8, 9]); // needs cin = 2·2 = 4
    match unit.forward_with(&engine, &bad) {
        Err(SubaccelError::KernelMismatch { expected_k, got_k }) => {
            assert_eq!(expected_k, 2 * (2 * 3 * 5));
            assert_eq!(got_k, 3 * 3 * 5);
        }
        other => panic!("expected KernelMismatch, got {other:?}"),
    }
}
