//! Engine properties: the parallel packed-pairing engine must be
//! *bit-identical* to the serial path across shapes, roundings, and
//! thread counts, and the packed layout must round-trip losslessly to
//! the per-filter pairing it was built from. No artifacts needed.

use subaccel::accel::{ConvEngine, LayerPairing, PackedPairing, SubConv2d};
use subaccel::nn::layers::conv2d;
use subaccel::tensor::Tensor;
use subaccel::util::{forall, Gen};

const ROUNDINGS: [f32; 4] = [0.0, 0.05, 0.2, 0.5];

/// Random conv problem: weights (cout, cin, kh, kw), bias, input
/// (batch, cin, h, w) with h, w ≥ kh, kw.
fn random_problem(g: &mut Gen) -> (Tensor, Tensor, Tensor, f32) {
    let cin = 1 + g.rng.below(3);
    let cout = 1 + g.rng.below(6);
    let k = [1, 3, 5][g.rng.below(3)];
    let h = k + g.rng.below(8);
    let w = k + g.rng.below(8);
    let batch = 1 + g.rng.below(3);
    let weight = Tensor::new(&[cout, cin, k, k], g.rng.vec_normal(cout * cin * k * k));
    let bias = Tensor::new(&[cout], g.rng.vec_normal(cout));
    let x = Tensor::new(&[batch, cin, h, w], g.rng.vec_normal(batch * cin * h * w));
    let rounding = ROUNDINGS[g.rng.below(ROUNDINGS.len())];
    (weight, bias, x, rounding)
}

#[test]
fn parallel_forward_is_bit_identical_to_serial() {
    // Persistent engines reused across cases — this is also the steady
    // state the pool is designed for (zero allocation after warmup).
    let engines: Vec<ConvEngine> =
        (1..=4).map(|t| ConvEngine::new(t).unwrap()).collect();
    forall("engine-bit-identical", 0xE2617E, 30, |g| {
        let (weight, bias, x, rounding) = random_problem(g);
        let unit = SubConv2d::compile(&weight, &bias, rounding);
        let (want, want_counts) = unit.forward(&x);
        for engine in &engines {
            let (out, counts) = unit
                .forward_with(engine, &x)
                .map_err(|e| format!("threads {}: {e}", engine.threads()))?;
            if out != want {
                return Err(format!(
                    "threads {}: output diverged (max |Δ| {})",
                    engine.threads(),
                    out.max_abs_diff(&want)
                ));
            }
            if counts != want_counts {
                return Err(format!("threads {}: op counts diverged", engine.threads()));
            }
        }
        Ok(())
    });
}

#[test]
fn tiled_kernel_is_bit_identical_to_reference() {
    // The tile-blocked microkernel must reproduce the untiled reference
    // (`forward_packed_reference`) bit-for-bit at every tile size —
    // including degenerate tiles (1 row), tiles larger than any output
    // (4096), and every thread count. Tiling and sharding only regroup
    // independent outputs; each output's reduction order is fixed.
    let tiles = [1usize, 3, 8, 64, 4096];
    let engines: Vec<ConvEngine> = tiles
        .iter()
        .flat_map(|&t| [1usize, 4].map(|threads| ConvEngine::with_tile_rows(threads, t).unwrap()))
        .collect();
    forall("tiled-vs-reference", 0x711ED, 15, |g| {
        let (weight, bias, x, _) = random_problem(g);
        let rounding = [0.0f32, 0.05][g.rng.below(2)];
        let unit = SubConv2d::compile(&weight, &bias, rounding);
        let (want, want_counts) =
            ConvEngine::forward_packed_reference(unit.packed(), unit.bias(), unit.geometry(), &x)
                .map_err(|e| format!("reference: {e}"))?;
        for engine in &engines {
            let tile = engine.tile_rows().expect("explicit tile");
            let (got, counts) = unit
                .forward_with(engine, &x)
                .map_err(|e| format!("tile {tile} t={}: {e}", engine.threads()))?;
            if got != want {
                return Err(format!(
                    "tile {tile} t={}: diverged from reference (max |Δ| {})",
                    engine.threads(),
                    got.max_abs_diff(&want)
                ));
            }
            if counts != want_counts {
                return Err(format!("tile {tile} t={}: op counts diverged", engine.threads()));
            }
        }
        Ok(())
    });
}

#[test]
fn strided_padded_engine_matches_dense_oracle() {
    let engine = ConvEngine::new(3).unwrap();
    forall("engine-geometry-oracle", 0x5EED5, 25, |g| {
        let (weight, bias, x, rounding) = random_problem(g);
        let stride = 1 + g.rng.below(2);
        let pad = g.rng.below(2);
        let unit = SubConv2d::compile_geo(&weight, &bias, rounding, stride, pad);
        let (got, _) = unit
            .forward_with(&engine, &x)
            .map_err(|e| format!("engine forward: {e}"))?;
        // oracle: dense conv over the SNAPPED weights (pairing changes
        // the weights, not the arithmetic)
        let snapped = LayerPairing::from_weights(&weight, rounding).modified_weights(&weight);
        let (want, _) = conv2d(&x, &snapped, &bias, stride, pad);
        let diff = got.max_abs_diff(&want);
        if diff > 1e-5 {
            return Err(format!("stride {stride} pad {pad}: max |Δ| {diff} > 1e-5"));
        }
        Ok(())
    });
}

#[test]
fn packed_pairing_roundtrips_losslessly() {
    forall("packed-roundtrip", 0xBEEF, 40, |g| {
        let cout = 1 + g.rng.below(8);
        let k_len = 1 + g.rng.below(60);
        let weight = Tensor::new(&[cout, k_len, 1, 1], g.rng.vec_normal(cout * k_len));
        let rounding = ROUNDINGS[g.rng.below(ROUNDINGS.len())];
        let lp = LayerPairing::from_weights(&weight, rounding);
        let back = PackedPairing::from_layer(&lp).to_layer();
        if back.k_len != lp.k_len || back.shape != lp.shape || back.rounding != lp.rounding {
            return Err("layer metadata changed in round-trip".into());
        }
        if back.filters.len() != lp.filters.len() {
            return Err("filter count changed in round-trip".into());
        }
        for (c, (a, b)) in lp.filters.iter().zip(&back.filters).enumerate() {
            if a.pair_i1 != b.pair_i1
                || a.pair_i2 != b.pair_i2
                || a.pair_k != b.pair_k
                || a.unp_idx != b.unp_idx
                || a.unp_w != b.unp_w
            {
                return Err(format!("filter {c} changed in round-trip"));
            }
        }
        Ok(())
    });
}

#[test]
fn kernel_mismatch_is_a_typed_error_not_a_panic() {
    use subaccel::error::SubaccelError;
    let mut g = subaccel::util::Rng::seed_from_u64(11);
    let weight = Tensor::new(&[4, 2, 3, 3], g.vec_normal(4 * 2 * 3 * 3));
    let bias = Tensor::zeros(&[4]);
    let unit = SubConv2d::compile(&weight, &bias, 0.1);
    let engine = ConvEngine::new(2).unwrap();
    // 3 input channels but pairing was compiled for 2 → K mismatch
    let bad = Tensor::zeros(&[1, 3, 8, 8]);
    match unit.forward_with(&engine, &bad) {
        Err(SubaccelError::KernelMismatch { expected_k, got_k }) => {
            assert_eq!(expected_k, 2 * 3 * 3);
            assert_eq!(got_k, 3 * 3 * 3);
        }
        other => panic!("expected KernelMismatch, got {other:?}"),
    }
}
