//! Coordinator integration: the full serving pipeline under concurrent
//! load, variant switching, backpressure, and clean shutdown. Skips when
//! artifacts are missing.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;
use subaccel::coordinator::{Backend, Coordinator, ServeConfig};
use subaccel::data::{load_dataset, load_weights};
use subaccel::error::SubaccelError;
use subaccel::nn::lenet5_from_params;
use subaccel::runtime::Variant;

const ART: &str = "artifacts";

fn artifacts_ready() -> bool {
    let ok = Path::new(ART).join("weights.bin").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

fn cfg(batch: usize) -> ServeConfig {
    ServeConfig::builder()
        .artifacts_dir(ART)
        .variant(Variant::XlaNative)
        .batch_size(batch)
        .max_wait(Duration::from_millis(1))
        .queue_cap(256)
        .workers(1)
        .build()
        .expect("test config is valid")
}

#[test]
fn serves_correct_results_under_concurrency() {
    if !artifacts_ready() {
        return;
    }
    let coord = Arc::new(Coordinator::start(cfg(8)).unwrap());
    let ds = Arc::new(load_dataset(Path::new(ART).join("dataset.bin")).unwrap());
    let model = lenet5_from_params(&load_weights(Path::new(ART).join("weights.bin")).unwrap());

    // expected predictions from the rust oracle
    let n = 48usize;
    let expected: Vec<usize> =
        (0..n).map(|i| model.infer(&ds.image32(i)).argmax_rows()[0]).collect();

    let handles: Vec<_> = (0..6)
        .map(|c| {
            let coord = coord.clone();
            let ds = ds.clone();
            std::thread::spawn(move || {
                let mut preds = Vec::new();
                for i in (c * 8)..(c * 8 + 8) {
                    let logits = loop {
                        match coord.classify(ds.image32(i)) {
                            Ok(l) => break l,
                            Err(_) => std::thread::sleep(Duration::from_micros(100)),
                        }
                    };
                    let pred = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(j, _)| j)
                        .unwrap();
                    preds.push((i, pred));
                }
                preds
            })
        })
        .collect();
    for h in handles {
        for (i, pred) in h.join().unwrap() {
            assert_eq!(pred, expected[i], "request {i} diverged from oracle");
        }
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.completed, n as u64);
    assert!(snap.batches >= (n / 8) as u64);
    assert_eq!(snap.rejected, 0);
}

#[test]
fn partial_batches_flush_on_deadline() {
    if !artifacts_ready() {
        return;
    }
    let coord = Coordinator::start(cfg(8)).unwrap();
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    // a single request must still complete (padded batch)
    let logits = coord.classify(ds.image32(0)).unwrap();
    assert_eq!(logits.len(), 10);
    let m = coord.metrics();
    assert!(m.mean_batch_size() <= 1.5);
    coord.shutdown();
}

#[test]
fn variant_switch_changes_weights_and_keeps_serving() {
    if !artifacts_ready() {
        return;
    }
    let coord = Coordinator::start(cfg(8)).unwrap();
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    let a = coord.classify(ds.image32(0)).unwrap();
    let pairs = coord.set_rounding(0.3).unwrap();
    assert!(pairs > 1000, "rounding 0.3 should combine heavily, got {pairs}");
    let b = coord.classify(ds.image32(0)).unwrap();
    // logits must differ (weights changed), but service stayed up
    let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
    assert!(diff > 1e-6, "variant switch had no effect");
    let back = coord.set_rounding(0.0).unwrap();
    assert_eq!(back, 0);
    let c = coord.classify(ds.image32(0)).unwrap();
    let diff0: f32 = a.iter().zip(&c).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
    assert!(diff0 < 1e-6, "rounding 0 should restore original weights");
    coord.shutdown();
}

#[test]
fn rejects_wrong_shape_and_applies_backpressure() {
    if !artifacts_ready() {
        return;
    }
    // queue_cap must be >= batch_size under the validating builder, so
    // exercise backpressure with the smallest legal queue for batch 8
    let c = ServeConfig::builder()
        .artifacts_dir(ART)
        .variant(Variant::XlaNative)
        .batch_size(8)
        .max_wait(Duration::from_millis(1))
        .queue_cap(8)
        .workers(1)
        .build()
        .unwrap();
    let coord = Coordinator::start(c).unwrap();
    // wrong shape fails fast with the typed error, not a stringly one
    let err = coord.submit(subaccel::tensor::Tensor::zeros(&[1, 1, 28, 28])).unwrap_err();
    match err {
        SubaccelError::BadShape { ref expected, ref got } => {
            assert_eq!(expected, &vec![1, 1, 32, 32]);
            assert_eq!(got, &vec![1, 1, 28, 28]);
        }
        other => panic!("expected BadShape, got {other}"),
    }
    // ... and the same error surfaces through the anyhow edge
    let err = coord.classify(subaccel::tensor::Tensor::zeros(&[1, 1, 28, 28])).unwrap_err();
    assert!(err.downcast_ref::<SubaccelError>().is_some(), "{err:#}");
    // flooding a tiny queue must produce rejections (fire-and-forget)
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    let mut rxs = Vec::new();
    let mut rejected = 0u64;
    for i in 0..64 {
        match coord.submit(ds.image32(i % ds.n)) {
            Ok(rx) => rxs.push(rx),
            Err(e) => {
                assert_eq!(e, SubaccelError::QueueFull, "only backpressure expected");
                rejected += 1;
            }
        }
    }
    // drain what was accepted
    for rx in rxs {
        let _ = rx.recv();
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.rejected, rejected);
    coord.shutdown();
}

#[test]
fn shutdown_drains_pending_requests() {
    if !artifacts_ready() {
        return;
    }
    let coord = Coordinator::start(cfg(32)).unwrap();
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    let rxs: Vec<_> = (0..5).map(|i| coord.submit(ds.image32(i)).unwrap()).collect();
    coord.shutdown(); // must flush the partial batch, not drop it
    for (i, rx) in rxs.into_iter().enumerate() {
        let logits = rx.recv().expect("reply delivered").expect("classified");
        assert_eq!(logits.len(), 10, "request {i}");
    }
}

#[test]
fn replicated_workers_serve_and_switch_together() {
    if !artifacts_ready() {
        return;
    }
    let c = ServeConfig::builder()
        .artifacts_dir(ART)
        .variant(Variant::XlaNative)
        .batch_size(8)
        .max_wait(Duration::from_millis(1))
        .queue_cap(256)
        .workers(2)
        .build()
        .unwrap();
    let coord = Arc::new(Coordinator::start(c).unwrap());
    let ds = Arc::new(load_dataset(Path::new(ART).join("dataset.bin")).unwrap());
    let model = lenet5_from_params(&load_weights(Path::new(ART).join("weights.bin")).unwrap());
    let expected: Vec<usize> =
        (0..32).map(|i| model.infer(&ds.image32(i)).argmax_rows()[0]).collect();

    // concurrent load across both replicas must match the oracle
    let handles: Vec<_> = (0..4)
        .map(|c_id| {
            let coord = coord.clone();
            let ds = ds.clone();
            std::thread::spawn(move || {
                (c_id * 8..c_id * 8 + 8)
                    .map(|i| {
                        let logits = coord.classify(ds.image32(i)).unwrap();
                        logits
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.total_cmp(b.1))
                            .map(|(j, _)| j)
                            .unwrap()
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    for (c_id, h) in handles.into_iter().enumerate() {
        for (k, pred) in h.join().unwrap().into_iter().enumerate() {
            assert_eq!(pred, expected[c_id * 8 + k]);
        }
    }

    // a variant switch must reach BOTH replicas before returning: every
    // post-switch request sees the new weights no matter which worker
    // serves it
    let before = coord.classify(ds.image32(0)).unwrap();
    let pairs = coord.set_rounding(0.3).unwrap();
    assert!(pairs > 1000);
    for _ in 0..8 {
        let after = coord.classify(ds.image32(0)).unwrap();
        let diff: f32 = before.iter().zip(&after).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(diff > 1e-6, "a replica is still serving old weights");
    }
}

#[test]
fn cpu_engine_backend_serves_without_compiled_artifacts() {
    if !artifacts_ready() {
        return;
    }
    // Backend::CpuEngine needs weights.bin but no .hlo.txt — and it is
    // not restricted to the compiled batch sizes
    let c = ServeConfig::builder()
        .artifacts_dir(ART)
        .backend(Backend::CpuEngine)
        .batch_size(6)
        .max_wait(Duration::from_millis(1))
        .queue_cap(64)
        .workers(1)
        .engine_threads(2)
        .build()
        .unwrap();
    let coord = Coordinator::start(c).unwrap();
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    let model = lenet5_from_params(&load_weights(Path::new(ART).join("weights.bin")).unwrap());
    for i in 0..12 {
        let logits = coord.classify(ds.image32(i)).unwrap();
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap();
        let oracle = model.infer(&ds.image32(i)).argmax_rows()[0];
        assert_eq!(pred, oracle, "cpu-engine backend diverged on image {i}");
    }
    // live rounding switch works on the CPU backend too
    let pairs = coord.set_rounding(0.3).unwrap();
    assert!(pairs > 1000, "rounding 0.3 should combine heavily, got {pairs}");
    let logits = coord.classify(ds.image32(0)).unwrap();
    assert_eq!(logits.len(), 10);
    coord.shutdown();
}

#[test]
fn missing_artifacts_fail_init_cleanly() {
    let dir = subaccel::util::TempDir::new().unwrap();
    let c = ServeConfig::builder().artifacts_dir(dir.path()).build().unwrap();
    match Coordinator::start(c) {
        Ok(_) => panic!("coordinator started without artifacts"),
        Err(e) => assert!(format!("{e:#}").contains("weights.bin"), "{e:#}"),
    }
}

#[test]
fn builder_validation_is_enforced_at_the_edge() {
    // no artifacts needed — validation happens before any thread spawns
    let err = ServeConfig::builder().workers(0).build().unwrap_err();
    assert!(matches!(err, SubaccelError::InvalidConfig { field: "workers", .. }), "{err}");
    let err = ServeConfig::builder().batch_size(8).queue_cap(4).build().unwrap_err();
    assert!(matches!(err, SubaccelError::InvalidConfig { field: "queue_cap", .. }), "{err}");
    let err = ServeConfig::builder()
        .backend(Backend::Pjrt(Variant::XlaNative))
        .batch_size(7)
        .build()
        .unwrap_err();
    assert!(matches!(err, SubaccelError::InvalidConfig { field: "batch_size", .. }), "{err}");
    // the same batch size is fine on the artifact-free CPU backend
    assert!(ServeConfig::builder().backend(Backend::CpuEngine).batch_size(7).build().is_ok());
}
