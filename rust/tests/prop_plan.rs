//! Plan/execute equivalence: the whole-network [`ExecutionPlan`] path
//! (`PairedModel::forward_with` → `PlanExecutor`) must be *bit-identical*
//! — outputs AND op counts — to the pre-refactor layer-by-layer paired
//! execution, where each conv layer was an independent [`SubConv2d`] and
//! every other layer allocated a fresh tensor. Covered: LeNet-5 (batch 1
//! and 2) and the AlexNet conv stack (MaxPool + ReLU + strided/padded
//! geometry), at rounding 0.0 and 0.05, on serial and multi-threaded
//! engines.

use subaccel::accel::{ConvEngine, ConvGeometry, SubConv2d};
use subaccel::nn::{
    alexnet, lenet5, Activation, ForwardCounts, Layer, LayerKind, Model, PairedModel,
};
use subaccel::tensor::Tensor;
use subaccel::util::{forall, Gen};

/// Same elementwise non-linearity the library applies, re-stated here so
/// the reference path is independent of the plan executor's code.
fn apply_act(act: Activation, t: &mut Tensor) -> u64 {
    let xs = t.data_mut();
    match act {
        Activation::None => 0,
        Activation::Tanh => {
            for v in xs.iter_mut() {
                *v = v.tanh();
            }
            xs.len() as u64
        }
        Activation::Relu => {
            for v in xs.iter_mut() {
                *v = v.max(0.0);
            }
            xs.len() as u64
        }
    }
}

/// The pre-refactor execution strategy, reconstructed: conv layers run
/// their own [`SubConv2d`] on the engine, everything else runs the plain
/// [`Layer::forward`] kernel, with a fresh tensor between layers.
struct Reference {
    layers: Vec<Layer>,
    units: Vec<Option<SubConv2d>>,
}

impl Reference {
    fn compile(model: &Model, rounding: f32) -> Self {
        let units = model
            .layers
            .iter()
            .map(|layer| match &layer.kind {
                LayerKind::Conv2d { weight, bias, stride, pad_h, pad_w, groups } => {
                    let geo = ConvGeometry {
                        kh: weight.shape()[2],
                        kw: weight.shape()[3],
                        stride: *stride,
                        pad_h: *pad_h,
                        pad_w: *pad_w,
                        groups: *groups,
                    };
                    Some(SubConv2d::compile_with(weight, bias, rounding, geo).unwrap())
                }
                _ => None,
            })
            .collect();
        Self { layers: model.layers.clone(), units }
    }

    fn forward(
        &self,
        engine: &ConvEngine,
        x: &Tensor,
    ) -> Result<(Tensor, ForwardCounts), String> {
        let mut counts = ForwardCounts::default();
        let mut h = x.clone();
        for (layer, unit) in self.layers.iter().zip(&self.units) {
            let c = match unit {
                Some(u) => {
                    let (mut y, mut c) =
                        u.forward_with(engine, &h).map_err(|e| e.to_string())?;
                    c.activations += apply_act(layer.act, &mut y);
                    h = y;
                    c
                }
                None => {
                    let (y, c) = layer.forward(&h);
                    h = y;
                    c
                }
            };
            counts.push(&layer.name, c);
        }
        Ok((h, counts))
    }
}

/// AlexNet truncated after pool5 + flatten: all five conv layers (the
/// strided/padded/MaxPool/ReLU geometry LeNet-5 lacks) on an input small
/// enough for a debug-mode test. (1, 3, 67, 67) → conv1 15×15 → pool1
/// 7×7 → conv2 7×7 → pool2 3×3 → conv3/4/5 3×3 → pool5 1×1 → (1, 256).
fn alexnet_convstack() -> Model {
    let mut layers = alexnet().layers;
    layers.truncate(9);
    Model::new("alexnet_convstack", layers)
}

#[test]
fn plan_forward_is_bit_identical_to_layer_by_layer() {
    let engines = [ConvEngine::serial(), ConvEngine::new(3).unwrap()];
    let nets: Vec<(Model, Vec<usize>)> = vec![
        (lenet5(), vec![1, 1, 32, 32]),
        (lenet5(), vec![2, 1, 32, 32]),
        (alexnet_convstack(), vec![1, 3, 67, 67]),
    ];
    // Algorithm 1 runs once per (net, rounding) — only inputs vary below
    let compiled: Vec<(Reference, PairedModel, &[usize])> = [0.0f32, 0.05]
        .iter()
        .flat_map(|&r| {
            nets.iter().map(move |(m, shape)| {
                (Reference::compile(m, r), PairedModel::compile(m, r), shape.as_slice())
            })
        })
        .collect();
    forall("plan-vs-layer-by-layer", 0x9_1A_2027, 3, |g: &mut Gen| {
        for (reference, paired, shape) in &compiled {
            let n: usize = shape.iter().product();
            let x = Tensor::new(shape, g.rng.vec_normal(n));
            for engine in &engines {
                let (want, want_counts) = reference.forward(engine, &x)?;
                let (got, got_counts) = paired
                    .forward_with(engine, &x)
                    .map_err(|e| format!("{} plan forward: {e}", paired.name()))?;
                if got != want {
                    return Err(format!(
                        "{} rounding {} threads {}: plan output diverged (max |Δ| {})",
                        paired.name(),
                        paired.rounding(),
                        engine.threads(),
                        got.max_abs_diff(&want)
                    ));
                }
                if got_counts != want_counts {
                    return Err(format!(
                        "{} rounding {} threads {}: plan op counts diverged",
                        paired.name(),
                        paired.rounding(),
                        engine.threads()
                    ));
                }
            }
        }
        Ok(())
    });
}
