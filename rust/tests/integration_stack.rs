//! Cross-language / cross-layer integration: python-written artifacts →
//! rust data plane → rust engine → PJRT runtime. These tests close the
//! loops DESIGN.md §6 promises:
//!
//! * rust dense engine ≡ python pure-jnp goldens,
//! * PJRT XLA-native artifact ≡ goldens,
//! * PJRT Pallas-kernel artifact ≡ goldens (the paper-integrated path),
//! * PJRT subconv artifact fed with *rust* Algorithm-1 tables ≡ rust
//!   subtractor unit (the core contribution, across the language gap),
//! * modified-weight variants agree across engines.
//!
//! All tests skip cleanly when `make artifacts` has not run.

use std::collections::HashMap;
use std::path::Path;
use subaccel::accel::LayerPairing;
use subaccel::data::{load_dataset, load_golden, load_weights};
use subaccel::nn::lenet5_from_params;
use subaccel::runtime::{tensor_to_literal, LeNet5Executor, Runtime, Variant};
use subaccel::tensor::Tensor;

const ART: &str = "artifacts";

fn artifacts_ready() -> bool {
    let ok = Path::new(ART).join("golden.bin").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

fn weights() -> HashMap<String, Tensor> {
    load_weights(Path::new(ART).join("weights.bin")).expect("weights.bin")
}

/// Max |a−b| over two logit tensors.
fn max_diff(a: &Tensor, b: &Tensor) -> f32 {
    a.max_abs_diff(b)
}

#[test]
fn rust_engine_matches_python_goldens() {
    if !artifacts_ready() {
        return;
    }
    let golden = load_golden(Path::new(ART).join("golden.bin")).unwrap();
    let model = lenet5_from_params(&weights());
    let n = golden.inputs.shape()[0];
    let per = 32 * 32;
    let mut worst = 0f32;
    for i in 0..n {
        let img = Tensor::new(&[1, 1, 32, 32], golden.inputs.data()[i * per..(i + 1) * per].to_vec());
        let logits = model.infer(&img);
        let want = Tensor::new(&[1, 10], golden.logits.data()[i * 10..(i + 1) * 10].to_vec());
        worst = worst.max(max_diff(&logits, &want));
    }
    assert!(worst < 2e-3, "rust engine vs python goldens: max diff {worst}");
}

#[test]
fn golden_loss_curve_is_decreasing() {
    if !artifacts_ready() {
        return;
    }
    let golden = load_golden(Path::new(ART).join("golden.bin")).unwrap();
    assert!(golden.loss_curve.len() >= 2, "training recorded {} epochs", golden.loss_curve.len());
    assert!(
        golden.loss_curve.last().unwrap() < golden.loss_curve.first().unwrap(),
        "loss did not decrease: {:?}",
        golden.loss_curve
    );
}

#[test]
fn pjrt_xla_native_matches_goldens() {
    if !artifacts_ready() {
        return;
    }
    pjrt_variant_matches_goldens(Variant::XlaNative, 2e-3);
}

#[test]
fn pjrt_pallas_matches_goldens() {
    if !artifacts_ready() {
        return;
    }
    // the Pallas path reorders the contraction (tiled matmul) → same tol
    pjrt_variant_matches_goldens(Variant::Pallas, 2e-3);
}

fn pjrt_variant_matches_goldens(variant: Variant, tol: f32) {
    let golden = load_golden(Path::new(ART).join("golden.bin")).unwrap();
    let w = weights();
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let exe = LeNet5Executor::load(&rt, ART, variant, 8, &w).expect("load artifact");
    let per = 32 * 32;
    let n = 8; // one compiled batch
    let mut batch = Vec::with_capacity(n * per);
    batch.extend_from_slice(&golden.inputs.data()[..n * per]);
    let logits = exe.execute(&Tensor::new(&[n, 1, 32, 32], batch)).expect("execute");
    let want = Tensor::new(&[n, 10], golden.logits.data()[..n * 10].to_vec());
    let diff = max_diff(&logits, &want);
    assert!(diff < tol, "{variant:?} vs goldens: max diff {diff}");
}

#[test]
fn pjrt_batch_sizes_agree() {
    if !artifacts_ready() {
        return;
    }
    let w = weights();
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let e1 = LeNet5Executor::load(&rt, ART, Variant::XlaNative, 1, &w).unwrap();
    let e8 = LeNet5Executor::load(&rt, ART, Variant::XlaNative, 8, &w).unwrap();
    let batch = ds.batch32(0, 8);
    let l8 = e8.execute(&batch).unwrap();
    for i in 0..8 {
        let img = ds.image32(i);
        let l1 = e1.execute(&img).unwrap();
        let row = Tensor::new(&[1, 10], l8.data()[i * 10..(i + 1) * 10].to_vec());
        let diff = max_diff(&l1, &row);
        assert!(diff < 1e-4, "batch-1 vs batch-8 disagree at {i}: {diff}");
    }
}

#[test]
fn modified_weight_variant_agrees_across_engines() {
    if !artifacts_ready() {
        return;
    }
    let base = weights();
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    let rounding = 0.05f32;

    // rust dense engine with modified weights
    let model = lenet5_from_params(&base);
    let mut m = model.clone();
    for info in model.conv_layers(&[1, 1, 32, 32]) {
        let p = LayerPairing::from_weights(&info.weight, rounding);
        m.set_conv_weights(&info.name, p.modified_weights(&info.weight));
    }

    // PJRT executor with install_variant (same preprocessing, same HLO)
    let rt = Runtime::cpu().unwrap();
    let mut exe = LeNet5Executor::load(&rt, ART, Variant::XlaNative, 1, &base).unwrap();
    let pairs = exe.install_variant(&base, rounding).unwrap();
    assert!(pairs > 0, "headline rounding must find pairs");

    for i in 0..8 {
        let img = ds.image32(i);
        let a = m.infer(&img);
        let b = exe.execute(&img).unwrap();
        let diff = max_diff(&a, &b);
        assert!(diff < 2e-3, "engines disagree at img {i}: {diff}");
    }
}

/// The deepest cross-language loop: rust Algorithm-1 pairing tables feed
/// the *python-lowered* subconv HLO (pairing tables are runtime args),
/// and the result must match the rust subtractor unit bit-for-bit-ish.
#[test]
fn pjrt_subconv_artifact_matches_rust_subconv_unit() {
    if !artifacts_ready() {
        return;
    }
    let base = weights();
    let rt = Runtime::cpu().unwrap();
    let exe = rt
        .load_hlo(Path::new(ART).join("subconv_c3_b1.hlo.txt"))
        .expect("subconv artifact");

    // layer C3 geometry: input (1, 6, 14, 14), 16 filters of 150 weights
    let w3 = &base["c3_w"];
    let b3 = &base["c3_b"];
    let rounding = 0.05f32;
    let pairing = LayerPairing::from_weights(w3, rounding);

    // padded tables with the artifact's fixed Pmax=75 / Umax=150
    let (pmax, umax) = (75usize, 150usize);
    let cout = 16usize;
    let mut i1 = vec![0i32; cout * pmax];
    let mut i2 = vec![0i32; cout * pmax];
    let mut pk = vec![0f32; cout * pmax];
    let mut iu = vec![0i32; cout * umax];
    let mut wu = vec![0f32; cout * umax];
    for (c, f) in pairing.filters.iter().enumerate() {
        for j in 0..f.n_pairs() {
            i1[c * pmax + j] = f.pair_i1[j] as i32;
            i2[c * pmax + j] = f.pair_i2[j] as i32;
            pk[c * pmax + j] = f.pair_k[j];
        }
        for j in 0..f.n_unpaired() {
            iu[c * umax + j] = f.unp_idx[j] as i32;
            wu[c * umax + j] = f.unp_w[j];
        }
    }

    // synthetic input through both paths
    let mut rng = subaccel::util::Rng::seed_from_u64(99);
    let x = Tensor::new(&[1, 6, 14, 14], rng.vec_range(6 * 14 * 14, -1.0, 1.0));

    let lit = |v: &[f32], shape: &[usize]| {
        tensor_to_literal(&Tensor::new(shape, v.to_vec())).unwrap()
    };
    let ilit = |v: &[i32], shape: &[i64]| {
        xla::Literal::vec1(v).reshape(shape).unwrap()
    };
    let inputs = vec![
        tensor_to_literal(&x).unwrap(),
        ilit(&i1, &[16, 75]),
        ilit(&i2, &[16, 75]),
        lit(&pk, &[16, 75]),
        ilit(&iu, &[16, 150]),
        lit(&wu, &[16, 150]),
        tensor_to_literal(b3).unwrap(),
    ];
    let got = exe.run(&inputs).expect("execute subconv artifact");

    let unit = subaccel::accel::SubConv2d::compile(w3, b3, rounding);
    let (want, counts) = unit.forward(&x);
    assert!(counts.subs > 0);
    assert_eq!(got.shape(), want.shape());
    let diff = max_diff(&got, &want);
    assert!(diff < 1e-4, "python-lowered subconv vs rust unit: max diff {diff}");
}

/// The fully-paired artifact: ALL conv layers run the subtractor datapath
/// inside the python-lowered HLO, fed with rust Algorithm-1 tables.
#[test]
fn fully_paired_artifact_serves_and_matches_engines() {
    if !artifacts_ready() {
        return;
    }
    let base = weights();
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let rounding = 0.05f32;
    let exe = subaccel::runtime::PairedLeNet5Executor::load(&rt, ART, 1, &base, rounding)
        .expect("paired artifact");
    assert_eq!(exe.pairs_per_layer().len(), 3);
    assert!(exe.pairs_per_layer().iter().sum::<usize>() > 20_000);

    // oracle: rust dense engine with modified weights
    let model = lenet5_from_params(&base);
    let mut m = model.clone();
    for info in model.conv_layers(&[1, 1, 32, 32]) {
        let p = LayerPairing::from_weights(&info.weight, rounding);
        m.set_conv_weights(&info.name, p.modified_weights(&info.weight));
    }
    for i in 0..8 {
        let img = ds.image32(i);
        let got = exe.execute(&img).unwrap();
        let want = m.infer(&img);
        let diff = max_diff(&got, &want);
        assert!(diff < 2e-3, "paired artifact vs rust engine at {i}: {diff}");
    }
}

#[test]
fn fully_paired_artifact_rounding_zero_matches_original_model() {
    if !artifacts_ready() {
        return;
    }
    let base = weights();
    let ds = load_dataset(Path::new(ART).join("dataset.bin")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = subaccel::runtime::PairedLeNet5Executor::load(&rt, ART, 1, &base, 0.0).unwrap();
    assert_eq!(exe.pairs_per_layer().iter().sum::<usize>(), 0);
    let model = lenet5_from_params(&base);
    for i in 0..4 {
        let img = ds.image32(i);
        let diff = max_diff(&exe.execute(&img).unwrap(), &model.infer(&img));
        assert!(diff < 2e-3, "rounding 0 must reproduce the original model: {diff}");
    }
}

#[test]
fn malformed_artifact_is_rejected() {
    let dir = subaccel::util::TempDir::new().unwrap();
    std::fs::write(dir.file("bad.hlo.txt"), "this is not HLO").unwrap();
    let rt = Runtime::cpu().unwrap();
    assert!(rt.load_hlo(dir.file("bad.hlo.txt")).is_err());
    assert!(rt.load_hlo(dir.file("missing.hlo.txt")).is_err());
}

#[test]
fn executor_rejects_wrong_batch_shape() {
    if !artifacts_ready() {
        return;
    }
    let w = weights();
    let rt = Runtime::cpu().unwrap();
    let exe = LeNet5Executor::load(&rt, ART, Variant::XlaNative, 8, &w).unwrap();
    let bad = Tensor::zeros(&[4, 1, 32, 32]);
    let err = exe.execute(&bad).unwrap_err().to_string();
    assert!(err.contains("compiled for batch"), "{err}");
}
