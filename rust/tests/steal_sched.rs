//! The work-stealing chunk queue, in isolation and under the engine.
//!
//! [`ChunkQueue`] is the whole scheduler: one atomic cursor handing out
//! half-open row ranges. These tests pin its contract — every row is
//! claimed **exactly once** (coverage bitmap, checked under real thread
//! races), claims are never empty, a panicking claimant loses only its
//! own chunk, and the queue stays consistent for the survivors. The
//! engine-level tests then pin the regression class the queue fixed:
//! the old even `⌈rows/threads⌉` split handed trailing workers empty
//! (or missing) shards when `rows % threads != 0` or `rows < threads`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use subaccel::accel::{steal_chunk_rows, ChunkQueue, ConvEngine, SubConv2d};
use subaccel::tensor::Tensor;
use subaccel::util::Rng;

/// Drain `queue` from `threads` racing OS threads; returns every claim.
fn drain_with_threads(queue: &ChunkQueue, threads: usize) -> Vec<(usize, usize)> {
    let claims = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                while let Some(c) = queue.claim() {
                    claims.lock().unwrap().push(c);
                }
            });
        }
    });
    claims.into_inner().unwrap()
}

/// Every row in `0..rows` appears in exactly one claim, no claim is
/// empty, and none reaches past `rows`.
fn assert_exact_cover(rows: usize, claims: &[(usize, usize)]) {
    let mut seen = vec![0u32; rows];
    for &(a, b) in claims {
        assert!(a < b && b <= rows, "bad claim ({a}, {b}) for {rows} rows");
        for s in &mut seen[a..b] {
            *s += 1;
        }
    }
    for (r, &n) in seen.iter().enumerate() {
        assert_eq!(n, 1, "row {r} claimed {n} times (want exactly once)");
    }
}

#[test]
fn racing_threads_claim_every_row_exactly_once() {
    for (rows, chunk, threads) in [
        (729, 24, 8),
        (100, 7, 4), // remainder chunk: 100 % 7 != 0
        (64, 64, 8), // single chunk, many threads
        (16, 1, 16),
        (5, 2, 3),
    ] {
        let queue = ChunkQueue::new(rows, chunk);
        let claims = drain_with_threads(&queue, threads);
        assert_exact_cover(rows, &claims);
        assert_eq!(claims.len(), queue.n_chunks(), "rows {rows} chunk {chunk}");
        // dry queues stay dry
        assert_eq!(queue.claim(), None);
    }
}

#[test]
fn few_rows_many_threads_still_feeds_every_core_it_can() {
    // 3 rows on 8 threads: the sizing hands out single-row chunks, so
    // three claimants get work and the rest drain to None immediately —
    // nobody receives an empty range (the old even-split failure mode).
    let rows = 3;
    let chunk = steal_chunk_rows(rows, 16, 8);
    assert_eq!(chunk, 1, "scarce rows must go out one at a time");
    let queue = ChunkQueue::new(rows, chunk);
    let claims = drain_with_threads(&queue, 8);
    assert_exact_cover(rows, &claims);
    assert_eq!(claims.len(), 3);
}

#[test]
fn single_chunk_serves_the_whole_range_once() {
    // chunk larger than the row count: one claim covers everything,
    // clamped to `rows`; every later claim (any thread) is None.
    let queue = ChunkQueue::new(4, 8);
    assert_eq!(queue.n_chunks(), 1);
    assert_eq!(queue.claim(), Some((0, 4)));
    assert_eq!(queue.claim(), None);
    assert_eq!(queue.claim(), None, "drained queue must stay drained");
}

#[test]
fn remainder_chunks_are_short_but_never_empty() {
    // The regression class from the even split: whenever the row count
    // doesn't divide evenly, the *last* claim shrinks — it never
    // becomes empty and never spills past the end.
    for rows in 1..50usize {
        for chunk in 1..=rows {
            let queue = ChunkQueue::new(rows, chunk);
            let mut claims = Vec::new();
            while let Some(c) = queue.claim() {
                claims.push(c);
            }
            assert_exact_cover(rows, &claims);
            let &(last0, last1) = claims.last().unwrap();
            assert!(last1 - last0 >= 1 && last1 == rows);
        }
    }
    // zero rows: nothing to claim, nothing to panic about
    let empty = ChunkQueue::new(0, 4);
    assert_eq!(empty.n_chunks(), 0);
    assert_eq!(empty.claim(), None);
}

#[test]
fn panicked_claimant_loses_only_its_chunk() {
    let queue = ChunkQueue::new(20, 3);
    let lost = std::cell::Cell::new(None);
    let r = catch_unwind(AssertUnwindSafe(|| {
        lost.set(queue.claim());
        panic!("worker died mid-chunk");
    }));
    assert!(r.is_err());
    let lost = lost.get().expect("claim before panic succeeded");
    // survivors drain the rest concurrently; together with the lost
    // chunk the cover is still exact — the panic neither re-issued its
    // chunk nor corrupted the cursor for anyone else
    let mut claims = drain_with_threads(&queue, 4);
    claims.push(lost);
    assert_exact_cover(20, &claims);
}

#[test]
fn steal_chunk_sizing_bounds() {
    for rows in [1usize, 3, 6, 64, 729, 10_000] {
        for tile in [1usize, 2, 16, 64] {
            for threads in [1usize, 2, 8, 64] {
                let c = steal_chunk_rows(rows, tile, threads);
                assert!(c >= 1, "rows {rows} tile {tile} t{threads}");
                // above one tile, chunks snap to whole tiles so in-chunk
                // tiling keeps its full depth
                if c > tile {
                    assert_eq!(c % tile, 0, "rows {rows} tile {tile} t{threads}");
                }
                // enough claims to rebalance when rows are plentiful
                if rows >= 8 * threads * tile {
                    let claims = (rows + c - 1) / c;
                    assert!(claims >= 2 * threads, "rows {rows} tile {tile} t{threads}: {claims}");
                }
            }
        }
    }
}

/// Engine-level regression for the even-split remainder class: row
/// counts that used to produce empty trailing shards (`rows < threads`,
/// `rows % threads != 0`) must run and stay bit-identical to the
/// untiled reference under the stealing scheduler.
#[test]
fn awkward_row_counts_are_bit_identical_under_stealing() {
    let mut rng = Rng::seed_from_u64(0x57EA1);
    let e8 = ConvEngine::new(8).unwrap();
    let e3 = ConvEngine::new(3).unwrap();
    // (batch, cin, h, w) with a 3×3 valid conv → rows = batch·oh·ow
    for (batch, h, w) in [
        (2usize, 3usize, 5usize), // 6 rows on 8 threads: rows < threads
        (5, 3, 3),                // 5 rows on 3 threads: remainder 2
        (1, 3, 3),                // 1 row: single chunk, everyone else idle
        (7, 4, 5),                // 42 rows on 8 threads: remainder 2
    ] {
        let w_t = Tensor::new(&[4, 2, 3, 3], rng.vec_range(4 * 2 * 9, -1.0, 1.0));
        let b_t = Tensor::new(&[4], rng.vec_range(4, -0.5, 0.5));
        let unit = SubConv2d::compile(&w_t, &b_t, 0.05);
        let x = Tensor::new(&[batch, 2, h, w], rng.vec_range(batch * 2 * h * w, -1.0, 1.0));
        let (want, want_counts) =
            ConvEngine::forward_packed_reference(unit.packed(), unit.bias(), unit.geometry(), &x)
                .unwrap();
        for engine in [&e8, &e3] {
            let (got, counts) = unit.forward_with(engine, &x).unwrap();
            assert_eq!(
                got.data(),
                want.data(),
                "t={} batch {batch} {h}x{w}: diverged from reference",
                engine.threads()
            );
            assert_eq!(counts, want_counts);
        }
    }
}
