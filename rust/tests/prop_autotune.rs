//! Autotune properties: whatever tile the plan-warm sweep picks, the
//! network's outputs are **bit-identical** to the untiled reference —
//! across thread counts, repeated warms, warm-start caches, and
//! override precedence. The sweep may be greedy, noisy, or cached; it
//! must never be able to perturb a logit.

use subaccel::accel::{
    AutotuneBudget, ConvEngine, SubConv2d, TileCache, TileDecision, TileSource,
};
use subaccel::exec::ExecutionPlan;
use subaccel::nn::layers::{Activation, Layer, LayerKind};
use subaccel::nn::{lenet5, Model};
use subaccel::tensor::Tensor;
use subaccel::util::{forall, Gen, Rng};

/// Random single-conv model plus a matching input: the smallest network
/// where the plan's autotuned tile is the only variable, so the
/// reference path (`forward_packed_reference`) is an exact oracle.
fn random_conv_model(g: &mut Gen) -> (Model, Tensor, Tensor, Tensor, usize, usize, f32) {
    let cin = 1 + g.rng.below(3);
    let cout = 1 + g.rng.below(6);
    let k = [1, 3, 5][g.rng.below(3)];
    let stride = 1 + g.rng.below(2);
    let pad = g.rng.below(2);
    let h = k + g.rng.below(8);
    let w = k + g.rng.below(8);
    let batch = 1 + g.rng.below(3);
    let rounding = [0.0f32, 0.05, 0.2][g.rng.below(3)];
    let weight = Tensor::new(&[cout, cin, k, k], g.rng.vec_normal(cout * cin * k * k));
    let bias = Tensor::new(&[cout], g.rng.vec_normal(cout));
    let x = Tensor::new(&[batch, cin, h, w], g.rng.vec_normal(batch * cin * h * w));
    let model = Model::new(
        "prop-conv",
        vec![Layer::new(
            "c0",
            LayerKind::Conv2d {
                weight: weight.clone(),
                bias: bias.clone(),
                stride,
                pad_h: pad,
                pad_w: pad,
                groups: 1,
            },
            Activation::None,
        )],
    );
    (model, weight, bias, x, stride, pad, rounding)
}

#[test]
fn cost_mode_sweep_is_engine_invariant_and_bit_identical() {
    // Cost-model mode reads no clocks: the decision must be a pure
    // function of the layer — identical on 1-, 2-, and 4-thread
    // engines, stable across repeated warms, and (like any tile)
    // bit-identical to the untiled reference.
    let engines: Vec<ConvEngine> = [1usize, 2, 4]
        .iter()
        .map(|&t| ConvEngine::new(t).unwrap())
        .collect();
    forall("autotune-cost-mode", 0xA07_00, 20, |g| {
        let (model, weight, bias, x, stride, pad, rounding) = random_conv_model(g);
        let unit = SubConv2d::compile_geo(&weight, &bias, rounding, stride, pad);
        let (want, _) =
            ConvEngine::forward_packed_reference(unit.packed(), unit.bias(), unit.geometry(), &x)
                .map_err(|e| format!("reference: {e}"))?;
        let budget = AutotuneBudget::default();
        let mut first: Option<Vec<TileDecision>> = None;
        for engine in &engines {
            let plan = ExecutionPlan::compile(&model, rounding, x.shape())
                .map_err(|e| format!("plan: {e}"))?;
            let mut exe = plan.into_executor();
            let d1 = exe.warm_autotuned(engine, &budget, None).to_vec();
            let d2 = exe.warm_autotuned(engine, &budget, None).to_vec();
            if d1 != d2 {
                return Err(format!("t={}: repeated warm changed decisions", engine.threads()));
            }
            if d1.len() != 1 || d1[0].tile_rows < 1 {
                return Err(format!("t={}: bad decisions {d1:?}", engine.threads()));
            }
            match &first {
                None => first = Some(d1),
                Some(f) => {
                    if *f != d1 {
                        return Err(format!(
                            "t={}: decisions depend on the engine: {f:?} vs {d1:?}",
                            engine.threads()
                        ));
                    }
                }
            }
            let got = exe.infer(engine, &x).map_err(|e| format!("infer: {e}"))?;
            if got.data() != want.data() {
                return Err(format!(
                    "t={}: autotuned output diverged (max |Δ| {})",
                    engine.threads(),
                    got.max_abs_diff(&want)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn measured_sweep_any_tile_is_bit_identical() {
    // Measured mode times real forwards, so the winning tile is
    // host-dependent — the property is that *whatever* it picks, the
    // output doesn't move by a bit, at any thread count.
    let engines = [ConvEngine::new(1).unwrap(), ConvEngine::new(4).unwrap()];
    forall("autotune-measured", 0xA07_11, 10, |g| {
        let (model, weight, bias, x, stride, pad, rounding) = random_conv_model(g);
        let unit = SubConv2d::compile_geo(&weight, &bias, rounding, stride, pad);
        let (want, _) =
            ConvEngine::forward_packed_reference(unit.packed(), unit.bias(), unit.geometry(), &x)
                .map_err(|e| format!("reference: {e}"))?;
        for engine in &engines {
            let plan = ExecutionPlan::compile(&model, rounding, x.shape())
                .map_err(|e| format!("plan: {e}"))?;
            let mut exe = plan.into_executor();
            let d = exe.warm_autotuned(engine, &AutotuneBudget::measured(1), None).to_vec();
            if d.len() != 1 || d[0].tile_rows < 1 {
                return Err(format!("t={}: bad decisions {d:?}", engine.threads()));
            }
            let got = exe.infer(engine, &x).map_err(|e| format!("infer: {e}"))?;
            if got.data() != want.data() {
                return Err(format!(
                    "t={}: tile {} diverged (max |Δ| {})",
                    engine.threads(),
                    d[0].tile_rows,
                    got.max_abs_diff(&want)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn constructor_override_wins_over_sweep_and_cache() {
    // Precedence rungs 1–2: an engine-wide tile pins every layer — the
    // sweep is skipped even when a cache offers a different answer.
    let mut rng = Rng::seed_from_u64(0x0BE55);
    let weight = Tensor::new(&[4, 2, 3, 3], rng.vec_normal(4 * 2 * 9));
    let bias = Tensor::new(&[4], rng.vec_normal(4));
    let x = Tensor::new(&[2, 2, 9, 9], rng.vec_normal(2 * 2 * 81));
    let model = Model::new(
        "prop-conv",
        vec![Layer::new(
            "c0",
            LayerKind::Conv2d {
                weight: weight.clone(),
                bias: bias.clone(),
                stride: 1,
                pad_h: 0,
                pad_w: 0,
                groups: 1,
            },
            Activation::None,
        )],
    );
    let mut cache = TileCache::default();
    cache.insert(TileCache::key("prop-conv", "c0"), 3);
    let engine = ConvEngine::with_tile_rows(2, 7).unwrap();
    let plan = ExecutionPlan::compile(&model, 0.05, x.shape()).unwrap();
    let mut exe = plan.into_executor();
    let d = exe.warm_autotuned(&engine, &AutotuneBudget::default(), Some(&cache)).to_vec();
    assert_eq!(d.len(), 1);
    assert_eq!(d[0].source, TileSource::Override);
    assert_eq!(d[0].tile_rows, 7);
    assert_eq!(d[0].candidates, 0, "override must skip the sweep");
    let unit = SubConv2d::compile(&weight, &bias, 0.05);
    let (want, _) =
        ConvEngine::forward_packed_reference(unit.packed(), unit.bias(), unit.geometry(), &x)
            .unwrap();
    let got = exe.infer(&engine, &x).unwrap();
    assert_eq!(got.data(), want.data(), "override tile diverged from reference");
}

#[test]
fn warm_start_cache_is_honored_without_an_override() {
    // Precedence rung 3: a recorded trajectory entry replaces the sweep
    // on engines with no hard override — and, like any tile, it cannot
    // move the output.
    let engine = ConvEngine::serial();
    if engine.tile_rows().is_some() {
        // SUBACCEL_TILE_ROWS is set in this environment; the override
        // path is covered above, and a cache test would be vacuous.
        return;
    }
    let mut rng = Rng::seed_from_u64(0xCAC4E);
    let weight = Tensor::new(&[3, 2, 3, 3], rng.vec_normal(3 * 2 * 9));
    let bias = Tensor::new(&[3], rng.vec_normal(3));
    let x = Tensor::new(&[2, 2, 8, 8], rng.vec_normal(2 * 2 * 64));
    let model = Model::new(
        "prop-conv",
        vec![Layer::new(
            "c0",
            LayerKind::Conv2d {
                weight: weight.clone(),
                bias: bias.clone(),
                stride: 1,
                pad_h: 0,
                pad_w: 0,
                groups: 1,
            },
            Activation::None,
        )],
    );
    let mut cache = TileCache::default();
    cache.insert(TileCache::key("prop-conv", "c0"), 2);
    let plan = ExecutionPlan::compile(&model, 0.05, x.shape()).unwrap();
    let mut exe = plan.into_executor();
    let d = exe.warm_autotuned(&engine, &AutotuneBudget::default(), Some(&cache)).to_vec();
    assert_eq!(d.len(), 1);
    assert_eq!(d[0].source, TileSource::WarmStart);
    assert_eq!(d[0].tile_rows, 2);
    let unit = SubConv2d::compile(&weight, &bias, 0.05);
    let (want, _) =
        ConvEngine::forward_packed_reference(unit.packed(), unit.bias(), unit.geometry(), &x)
            .unwrap();
    let got = exe.infer(&engine, &x).unwrap();
    assert_eq!(got.data(), want.data(), "warm-started tile diverged from reference");
}

#[test]
fn tuned_lenet5_matches_plain_lenet5_exactly() {
    // Whole-network, multi-layer, through pooling and dense layers: a
    // tuned plan and an untuned plan of the same net produce the same
    // logits bit-for-bit at every thread count.
    let m = lenet5();
    let mut rng = Rng::seed_from_u64(0x1E4E7);
    let x = Tensor::new(&[2, 1, 32, 32], rng.vec_range(2 * 1024, 0.0, 1.0));
    for threads in [1usize, 2, 4] {
        let engine = ConvEngine::new(threads).unwrap();
        let mut plain = ExecutionPlan::compile(&m, 0.05, x.shape()).unwrap().into_executor();
        plain.warm();
        let want = plain.infer(&engine, &x).unwrap();
        let mut tuned = ExecutionPlan::compile(&m, 0.05, x.shape()).unwrap().into_executor();
        let d = tuned.warm_autotuned(&engine, &AutotuneBudget::default(), None).to_vec();
        assert_eq!(d.len(), 3, "lenet5 has three conv layers to tune");
        let got = tuned.infer(&engine, &x).unwrap();
        assert_eq!(
            got.data(),
            want.data(),
            "t={threads}: tuned lenet5 diverged from the untuned plan"
        );
    }
}
