//! Proves the plan executor's headline claim: after warm-up, a
//! steady-state [`PlanExecutor::forward_into`] performs **zero** heap
//! allocations — activations ping-pong between pre-sized scratch buffers
//! and the engine reuses its im2col scratch.
//!
//! Counts allocations with a `#[global_allocator]` wrapper, which is
//! process-global — so this test lives alone in its own integration-test
//! binary. Runs on [`ConvEngine::serial`]: the multi-threaded path hands
//! row shards to workers through channels, which allocate per send by
//! design (that cost is the pool's, not the plan's; the stealing chunk
//! queue itself lives on the dispatcher's stack and allocates nothing).
//! Also covers the autotuned warm path: the tile sweep allocates at warm
//! time only, and the steady state it pins stays allocation-free.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use subaccel::accel::{AutotuneBudget, ConvEngine};
use subaccel::exec::ExecutionPlan;
use subaccel::nn::lenet5;
use subaccel::tensor::Tensor;

/// System allocator with a global counter on every acquiring call
/// (`alloc`, `realloc`, `alloc_zeroed`).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_forward_into_allocates_nothing() {
    // Three single-threaded engines: the per-layer tile heuristic, a
    // forced 3-row tile — the latter refills the streaming im2col strip
    // many times per layer, proving strip reuse (not just strip growth)
    // is allocation-free — and an autotuned warm: the measured tile
    // sweep may allocate freely (it runs at warm time, where the plan's
    // zero-alloc contract does not apply), but the steady state it
    // leaves behind must still allocate nothing. One test fn on purpose:
    // the allocation counter is process-global, and parallel test
    // threads would corrupt the before/after diffs.
    for (label, engine, autotuned) in [
        ("heuristic tile", ConvEngine::serial(), false),
        ("forced tile=3", ConvEngine::with_tile_rows(1, 3).unwrap(), false),
        ("autotuned warm", ConvEngine::serial(), true),
    ] {
        let plan = ExecutionPlan::compile(&lenet5(), 0.05, &[2, 1, 32, 32]).unwrap();
        let mut exe = plan.into_executor();
        if autotuned {
            // measured mode so the sweep exercises the real (allocating)
            // timing path, not just the cost model
            let decisions = exe.warm_autotuned(&engine, &AutotuneBudget::measured(1), None);
            assert!(!decisions.is_empty(), "[{label}] sweep produced no decisions");
        } else {
            exe.warm();
        }
        let x = Tensor::full(&[2, 1, 32, 32], 0.3);
        let mut out = Vec::new();
        // warm-up: grows `out` and the engine's im2col strip
        let mut baseline = Vec::new();
        for _ in 0..2 {
            exe.forward_into(&engine, &x, &mut out).unwrap();
            baseline = out.clone();
        }

        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..5 {
            let shape = exe.forward_into(&engine, &x, &mut out).unwrap();
            assert_eq!(shape, &[2, 10]);
        }
        let allocs = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            allocs, 0,
            "[{label}] steady-state forward_into performed {allocs} heap allocations"
        );
        // and it still computes: same logits as the warm-up passes
        assert_eq!(out.len(), 20);
        assert_eq!(out, baseline, "[{label}] steady-state output diverged from warm-up output");
    }
}
